//! Byte-accounted tree all-reduce over canonical data shards.
//!
//! The reduction tree is indexed by **shard**, never by worker: stride
//! doubling over shard slots (`s[i] += s[i+stride]`) gives a fixed
//! binary combine order that depends only on the shard count, so the
//! summed gradient is bit-identical however many workers execute the
//! shards — the comm-side half of the dist engine's worker-count
//! invariance (the data-side half is [`crate::data::batch::ShardSampler`]).
//!
//! Communication volume is *accounted*, not simulated: an edge of the
//! tree whose two shards live on different workers would cross the wire
//! in a real deployment, so it is charged `payload` bytes for the reduce
//! leg and `payload` again for the broadcast leg of the all-reduce
//! (workers below the root need the reduced result back). Edges interior
//! to one worker are free. [`CommStats`] keeps the low-rank r×n traffic
//! separate from dense traffic so the bench can report the projected
//! all-reduce saving against a dense-gradient baseline — the analytic
//! twin lives in [`crate::memcount::allreduce_layer_bytes`].

//!
//! PR 6 hardens this layer: every cross-worker payload is checksummed
//! (an xxhash-style 64-bit mix over the f32 bit patterns), corruption or
//! loss is detected and resent under a bounded deterministic backoff,
//! and the fault/retry accounting is folded into [`CommStats`] in
//! counters *separate* from the payload byte counters — so a run that
//! survived injected corruption has byte-identical payload accounting to
//! the fault-free run, with only the retry counters differing.

//!
//! PR 8 adds the quantized wire: with a non-f32 [`Codec`]
//! (`--wire-dtype bf16|int8`), every tree edge ships the *encoded*
//! payload — the source shard is encoded, the checksum is computed over
//! the quantized bytes, and the receiving shard owner decodes and
//! accumulates in f32. The encode→decode transform is applied uniformly
//! at **every** edge, cross-worker and intra-worker alike, so the
//! reduced value is a pure function of the shard count and the shard
//! values — the worker-count invariance contract survives quantization.
//! Byte counters charge the encoded size (`Codec::encoded_len`), which
//! is what `BENCH_quant.json` measures.

use crate::faults::{FaultInjector, FaultKind};
use crate::quant::{Codec, QuantDtype, QuantError};
use crate::telemetry::{self, span, SpanKind};

/// Shard→worker placement: `shards` canonical shards in contiguous
/// blocks of `shards / workers` per worker (validated divisible).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub shards: usize,
    pub workers: usize,
}

impl Topology {
    pub fn new(shards: usize, workers: usize) -> Topology {
        assert!(workers >= 1 && shards >= workers, "need shards >= workers >= 1");
        assert_eq!(shards % workers, 0, "workers must divide shards");
        Topology { shards, workers }
    }

    /// Worker owning shard `s` (contiguous blocks).
    pub fn owner(&self, s: usize) -> usize {
        debug_assert!(s < self.shards);
        s / (self.shards / self.workers)
    }

    /// Number of cross-worker edges in the stride-doubling tree over the
    /// shard slots (`workers - 1` when the per-worker block size is a
    /// power of two, slightly more otherwise).
    pub fn cross_edges(&self) -> u64 {
        let mut edges = 0u64;
        let mut stride = 1;
        while stride < self.shards {
            let mut i = 0;
            while i + stride < self.shards {
                if self.owner(i) != self.owner(i + stride) {
                    edges += 1;
                }
                i += 2 * stride;
            }
            stride *= 2;
        }
        edges
    }
}

/// Tree-reduce `items` (one per shard, index order) by summing the f32
/// buffers `get` exposes into item 0, in stride-doubling order. Returns
/// the number of cross-worker edges (for byte accounting). The combine
/// order depends only on `items.len()`, so the sum in slot 0 is
/// bit-identical for every worker count.
pub fn tree_reduce_with<T, F>(items: &mut [T], mut get: F, topo: &Topology) -> u64
where
    F: FnMut(&mut T) -> &mut [f32],
{
    let n = items.len();
    assert_eq!(n, topo.shards, "one slot per shard");
    let mut edges = 0u64;
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (head, tail) = items.split_at_mut(i + stride);
            let dst = get(&mut head[i]);
            let src = get(&mut tail[0]);
            debug_assert_eq!(dst.len(), src.len(), "shard payloads must agree");
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                *d += *s;
            }
            if topo.owner(i) != topo.owner(i + stride) {
                edges += 1;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    edges
}

/// Seed folded into every payload checksum (domain separation from the
/// training RNG streams).
pub const CHECKSUM_SEED: u64 = 0xC0_55_C0_55;

/// Resend attempts before a transfer is declared failed.
pub const MAX_RETRIES: u32 = 3;

/// xxhash-style 64-bit checksum over the f32 bit patterns of a payload.
/// One multiply-rotate round per word — cheap enough to run on every
/// cross-worker transfer (see EXPERIMENTS.md §Robustness for the
/// measured overhead).
pub fn checksum(data: &[f32], seed: u64) -> u64 {
    const P1: u64 = 0x9E37_79B1_85EB_CA87;
    const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
    const P3: u64 = 0x1656_67B1_9E37_79F9;
    let mut h = seed ^ P1 ^ (data.len() as u64).wrapping_mul(P2);
    for &x in data {
        h ^= (x.to_bits() as u64).wrapping_mul(P2);
        h = h.rotate_left(31).wrapping_mul(P1).wrapping_add(P3);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(P2);
    h ^= h >> 29;
    h = h.wrapping_mul(P3);
    h ^ (h >> 32)
}

/// xxhash-style 64-bit checksum over raw bytes (8-byte little-endian
/// words, zero-padded tail, length folded in) — the quantized-wire
/// sibling of [`checksum`]. Computed over the *encoded* payload, so a
/// flipped wire byte is caught before the receiver ever dequantizes.
pub fn checksum_bytes(data: &[u8], seed: u64) -> u64 {
    const P1: u64 = 0x9E37_79B1_85EB_CA87;
    const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
    const P3: u64 = 0x1656_67B1_9E37_79F9;
    let mut h = seed ^ P1 ^ (data.len() as u64).wrapping_mul(P2);
    let mut words = data.chunks_exact(8);
    for w in words.by_ref() {
        let x = u64::from_le_bytes([w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7]]);
        h ^= x.wrapping_mul(P2);
        h = h.rotate_left(31).wrapping_mul(P1).wrapping_add(P3);
    }
    let rem = words.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        let x = u64::from_le_bytes(tail);
        h ^= x.wrapping_mul(P2);
        h = h.rotate_left(31).wrapping_mul(P1).wrapping_add(P3);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(P2);
    h ^= h >> 29;
    h = h.wrapping_mul(P3);
    h ^ (h >> 32)
}

/// A cross-worker transfer that could not be completed within
/// [`MAX_RETRIES`] resends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommError {
    /// Receiver kept seeing a checksum mismatch (persistent corruption).
    ChecksumMismatch { attempts: u32 },
    /// Receiver kept timing out (persistent loss).
    Dropped { attempts: u32 },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::ChecksumMismatch { attempts } => {
                write!(f, "payload checksum mismatch after {attempts} attempts")
            }
            CommError::Dropped { attempts } => {
                write!(f, "payload dropped after {attempts} attempts")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Hardened variant of [`tree_reduce_with`]: identical combine order and
/// bit-identical sums, but every cross-worker transfer is checksummed at
/// the sender and verified at the receiver, with faults (injected via an
/// armed [`FaultInjector`]) detected and retried under a deterministic
/// exponential backoff. Payload byte counters in `stats` are charged by
/// the caller exactly as for the plain reduce; this function only adds
/// the checksum/fault/retry accounting, so fault-free and
/// recovered-after-fault runs agree byte-for-byte on payload traffic.
pub fn tree_reduce_hardened<T, F>(
    items: &mut [T],
    mut get: F,
    topo: &Topology,
    mut faults: Option<&mut FaultInjector>,
    stats: &mut CommStats,
) -> Result<u64, CommError>
where
    F: FnMut(&mut T) -> &mut [f32],
{
    let _sp = span(SpanKind::AllReduce);
    let n = items.len();
    assert_eq!(n, topo.shards, "one slot per shard");
    let mut edges = 0u64;
    let mut stride = 1;
    let mut wire: Vec<f32> = Vec::new();
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (head, tail) = items.split_at_mut(i + stride);
            let dst = get(&mut head[i]);
            let src = get(&mut tail[0]);
            debug_assert_eq!(dst.len(), src.len(), "shard payloads must agree");
            if topo.owner(i) != topo.owner(i + stride) {
                edges += 1;
                transfer(src, &mut wire, faults.as_deref_mut(), stats)?;
            }
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                *d += *s;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    Ok(edges)
}

/// Simulate one checksummed cross-worker transfer of `src`. Faults are
/// applied to a scratch "wire" copy so the canonical payload is never
/// perturbed: after a successful (possibly retried) transfer the
/// receiver holds bytes identical to `src`, which keeps the reduce
/// arithmetic bit-identical to the fault-free run.
fn transfer(
    src: &[f32],
    wire: &mut Vec<f32>,
    mut faults: Option<&mut FaultInjector>,
    stats: &mut CommStats,
) -> Result<(), CommError> {
    let _sp = span(SpanKind::Transfer);
    let sent = {
        let _cs = span(SpanKind::ChecksumVerify);
        checksum(src, CHECKSUM_SEED)
    };
    stats.checksummed_payloads += 1;
    let payload_bytes = (src.len() * 4) as u64;
    // Telemetry instruments (dedicated statics — no registry lookup on
    // the wire path; `CommStats` is pinned by tests and stays untouched).
    if telemetry::spans_enabled() {
        telemetry::COMM_BYTES.record(payload_bytes);
    }
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        let fault = match faults.as_deref_mut() {
            Some(inj) => inj.payload_fault(attempts == 1),
            None => None,
        };
        match fault {
            None => {
                // Verify at the receiver when fault tolerance is armed;
                // the unarmed steady path pays the sender-side hash only.
                if faults.is_some() {
                    let _cs = span(SpanKind::ChecksumVerify);
                    if checksum(src, CHECKSUM_SEED) != sent {
                        return Err(CommError::ChecksumMismatch { attempts });
                    }
                }
                return Ok(());
            }
            Some(FaultKind::Delay) => {
                stats.delayed_payloads += 1;
                stats.backoff_units += 1;
                return Ok(());
            }
            Some(FaultKind::Duplicate) => {
                // Second copy is discarded by sequence id; add-once.
                stats.duplicate_payloads += 1;
                return Ok(());
            }
            Some(FaultKind::Drop) => {
                stats.dropped_payloads += 1;
            }
            Some(FaultKind::BitFlip) => {
                wire.clear();
                wire.extend_from_slice(src);
                if let Some(inj) = faults.as_deref_mut() {
                    inj.flip_word(wire);
                }
                let got = checksum(wire, CHECKSUM_SEED);
                debug_assert_ne!(got, sent, "single-bit flip must change the checksum");
                stats.checksum_failures += 1;
            }
            Some(other) => panic!("step-scoped fault {other:?} reached the comm layer"),
        }
        if attempts > MAX_RETRIES {
            return Err(match fault {
                Some(FaultKind::Drop) => CommError::Dropped { attempts },
                _ => CommError::ChecksumMismatch { attempts },
            });
        }
        stats.retries += 1;
        stats.retry_bytes += payload_bytes;
        stats.backoff_units += 1u64 << (attempts - 1);
        if telemetry::spans_enabled() {
            telemetry::COMM_RETRIES.inc();
        }
    }
}

/// Exchange one round of shard-indexed rollback votes: every cross
/// edge of the reduction tree carries the full vote payload (one f32
/// word per shard proposal plus one agreed-bound slot) through the
/// same checksummed, retried [`transfer`] path as gradient payloads —
/// an injected drop/flip on a vote payload is detected and resent, so
/// the decision every worker folds is the decision that was cast.
/// Votes always cross the wire in f32 (they are control words, not
/// gradients), whatever the payload codec. Byte accounting lands in
/// [`CommStats::record_rollback_votes`].
pub fn exchange_votes(
    payload: &[f32],
    topo: &Topology,
    mut faults: Option<&mut FaultInjector>,
    stats: &mut CommStats,
) -> Result<(), CommError> {
    let mut wire: Vec<f32> = Vec::new();
    for _ in 0..topo.cross_edges() {
        transfer(payload, &mut wire, faults.as_deref_mut(), stats)?;
    }
    stats.record_rollback_votes(topo.cross_edges(), payload.len() as u64);
    Ok(())
}

/// Quantized-wire variant of [`tree_reduce_hardened`]: same shard-indexed
/// stride-doubling tree, same checksummed/retried cross-worker transfers,
/// but every edge ships `codec`-encoded bytes and the receiving shard
/// owner decodes and accumulates in f32.
///
/// The encode→decode transform is applied at **every** edge — including
/// edges interior to one worker, which a real deployment would serve
/// from local memory. That uniformity is deliberate: it makes the
/// reduced value a pure function of `(shard count, shard values, codec)`
/// so any worker count lands on bit-identical sums, at the cost of
/// quantizing a few edges that did not strictly need it. An edge whose
/// source holds a non-finite value (which blockwise int8 cannot encode)
/// deterministically falls back to the f32 wire for that edge, keeping
/// the NaN visible to the engine's numerical guards downstream.
///
/// With an f32 codec this *is* [`tree_reduce_hardened`] — same code
/// path, bit-for-bit, byte-for-byte.
pub fn tree_reduce_quantized<T, F>(
    items: &mut [T],
    mut get: F,
    topo: &Topology,
    codec: Codec,
    mut faults: Option<&mut FaultInjector>,
    stats: &mut CommStats,
) -> Result<u64, CommError>
where
    F: FnMut(&mut T) -> &mut [f32],
{
    if codec.dtype == QuantDtype::F32 {
        return tree_reduce_hardened(items, get, topo, faults, stats);
    }
    let _sp = span(SpanKind::AllReduce);
    let n = items.len();
    assert_eq!(n, topo.shards, "one slot per shard");
    let mut edges = 0u64;
    let mut stride = 1;
    let mut enc: Vec<u8> = Vec::new();
    let mut wire_bytes: Vec<u8> = Vec::new();
    let mut wire_f32: Vec<f32> = Vec::new();
    let mut deq: Vec<f32> = Vec::new();
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (head, tail) = items.split_at_mut(i + stride);
            let dst = get(&mut head[i]);
            let src = get(&mut tail[0]);
            debug_assert_eq!(dst.len(), src.len(), "shard payloads must agree");
            let cross = topo.owner(i) != topo.owner(i + stride);
            if cross {
                edges += 1;
            }
            match codec.encode_into(src, &mut enc) {
                Ok(()) => {
                    if cross {
                        transfer_bytes(
                            &enc,
                            (src.len() * 4) as u64,
                            &mut wire_bytes,
                            faults.as_deref_mut(),
                            stats,
                        )?;
                    }
                    deq.resize(src.len(), 0.0);
                    codec.decode_into(&enc, &mut deq).expect("self-encoded payload decodes");
                    for (d, s) in dst.iter_mut().zip(deq.iter()) {
                        *d += *s;
                    }
                }
                Err(QuantError::NonFinite { .. }) => {
                    // Deterministic per-edge f32 fallback: finiteness is a
                    // function of the shard values alone, so every worker
                    // count takes the same branch.
                    if cross {
                        transfer(src, &mut wire_f32, faults.as_deref_mut(), stats)?;
                    }
                    for (d, s) in dst.iter_mut().zip(src.iter()) {
                        *d += *s;
                    }
                }
                Err(e @ QuantError::Malformed { .. }) => {
                    unreachable!("encode cannot report a length error: {e}")
                }
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    Ok(edges)
}

/// Simulate one checksummed cross-worker transfer of an encoded payload
/// (the quantized-wire sibling of [`transfer`]). The checksum covers the
/// quantized bytes; injected faults perturb a scratch wire copy so the
/// canonical encoding is never touched, and after a successful transfer
/// the receiver holds bytes identical to `enc`.
fn transfer_bytes(
    enc: &[u8],
    logical_bytes: u64,
    wire: &mut Vec<u8>,
    mut faults: Option<&mut FaultInjector>,
    stats: &mut CommStats,
) -> Result<(), CommError> {
    let _sp = span(SpanKind::Transfer);
    let sent = {
        let _cs = span(SpanKind::ChecksumVerify);
        checksum_bytes(enc, CHECKSUM_SEED)
    };
    stats.checksummed_payloads += 1;
    let payload_bytes = enc.len() as u64;
    if telemetry::spans_enabled() {
        telemetry::COMM_BYTES.record(payload_bytes);
        telemetry::WIRE_QUANT_BYTES.add(payload_bytes);
        telemetry::WIRE_LOGICAL_BYTES.add(logical_bytes);
    }
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        let fault = match faults.as_deref_mut() {
            Some(inj) => inj.payload_fault(attempts == 1),
            None => None,
        };
        match fault {
            None => {
                if faults.is_some() {
                    let _cs = span(SpanKind::ChecksumVerify);
                    if checksum_bytes(enc, CHECKSUM_SEED) != sent {
                        return Err(CommError::ChecksumMismatch { attempts });
                    }
                }
                return Ok(());
            }
            Some(FaultKind::Delay) => {
                stats.delayed_payloads += 1;
                stats.backoff_units += 1;
                return Ok(());
            }
            Some(FaultKind::Duplicate) => {
                stats.duplicate_payloads += 1;
                return Ok(());
            }
            Some(FaultKind::Drop) => {
                stats.dropped_payloads += 1;
            }
            Some(FaultKind::BitFlip) => {
                wire.clear();
                wire.extend_from_slice(enc);
                if let Some(inj) = faults.as_deref_mut() {
                    inj.flip_byte(wire);
                }
                let got = checksum_bytes(wire, CHECKSUM_SEED);
                debug_assert_ne!(got, sent, "single-bit flip must change the checksum");
                stats.checksum_failures += 1;
            }
            Some(other) => panic!("step-scoped fault {other:?} reached the comm layer"),
        }
        if attempts > MAX_RETRIES {
            return Err(match fault {
                Some(FaultKind::Drop) => CommError::Dropped { attempts },
                _ => CommError::ChecksumMismatch { attempts },
            });
        }
        stats.retries += 1;
        stats.retry_bytes += payload_bytes;
        stats.backoff_units += 1u64 << (attempts - 1);
        if telemetry::spans_enabled() {
            telemetry::COMM_RETRIES.inc();
        }
    }
}

/// Measured communication volume of a distributed run.
///
/// `lowrank_bytes` is the steady-state projected-gradient traffic (the
/// r×n payloads that replace dense m×n exchanges); `refresh_dense_bytes`
/// is the dense gradient traffic of consensus-triggered subspace
/// refreshes; `other_dense_bytes` covers tensors that are dense in every
/// method (embedding, norm vectors, full-rank baselines).
/// `dense_equiv_bytes` is what a dense-gradient baseline would have sent
/// for the *projected* matrices over the same steps — the numerator of
/// the reported comm saving.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    pub lowrank_bytes: u64,
    pub refresh_dense_bytes: u64,
    pub other_dense_bytes: u64,
    pub dense_equiv_bytes: u64,
    pub control_bytes: u64,
    pub lowrank_reduces: u64,
    pub dense_reduces: u64,
    /// Cross-worker transfers that carried a checksum (all of them).
    pub checksummed_payloads: u64,
    /// Receiver-side checksum mismatches (corrupted payloads caught).
    pub checksum_failures: u64,
    /// Payloads that never arrived and timed out.
    pub dropped_payloads: u64,
    /// Duplicate deliveries discarded by sequence id.
    pub duplicate_payloads: u64,
    /// Payloads that arrived late (no resend needed).
    pub delayed_payloads: u64,
    /// Resends after a detected drop/corruption.
    pub retries: u64,
    /// Bytes moved by resends (kept out of the payload byte counters so
    /// recovered runs match fault-free runs byte-for-byte there).
    pub retry_bytes: u64,
    /// Deterministic exponential-backoff units spent waiting.
    pub backoff_units: u64,
}

impl CommStats {
    /// Account one projected-gradient all-reduce: `payload` low-rank
    /// bytes per edge per leg (reduce + broadcast), against a dense
    /// baseline of `dense_equiv` bytes per edge per leg.
    pub fn record_lowrank(&mut self, edges: u64, payload: u64, dense_equiv: u64) {
        self.lowrank_bytes += 2 * edges * payload;
        self.dense_equiv_bytes += 2 * edges * dense_equiv;
        self.lowrank_reduces += 1;
    }

    /// Account the dense gradient all-reduce of a consensus refresh (the
    /// dense baseline sends nothing extra on these steps, so no
    /// `dense_equiv` contribution).
    pub fn record_refresh_dense(&mut self, edges: u64, payload: u64) {
        self.refresh_dense_bytes += 2 * edges * payload;
        self.dense_reduces += 1;
    }

    /// Account a dense all-reduce of a tensor that is dense in every
    /// method (embedding, norms, full-rank baseline matrices).
    pub fn record_other_dense(&mut self, edges: u64, payload: u64) {
        self.other_dense_bytes += 2 * edges * payload;
        self.dense_reduces += 1;
    }

    /// Account a consensus vote gather + decision broadcast (1 byte per
    /// shard vote, 1 byte decision, per cross edge).
    pub fn record_votes(&mut self, edges: u64, shards: u64) {
        self.control_bytes += edges * (shards + 1);
    }

    /// Account a rollback-consensus vote exchange: `words` f32 control
    /// words (one per shard proposal + one agreed-bound slot) per cross
    /// edge ([`exchange_votes`]).
    pub fn record_rollback_votes(&mut self, edges: u64, words: u64) {
        self.control_bytes += edges * 4 * words;
    }

    /// All bytes this run actually moved.
    pub fn total_bytes(&self) -> u64 {
        self.lowrank_bytes + self.refresh_dense_bytes + self.other_dense_bytes + self.control_bytes
    }

    /// Dense-baseline / actual ratio for the projected matrices,
    /// including refresh traffic (the honest end-to-end saving).
    pub fn reduction_vs_dense(&self) -> f64 {
        let actual = (self.lowrank_bytes + self.refresh_dense_bytes) as f64;
        if actual == 0.0 {
            return f64::NAN;
        }
        self.dense_equiv_bytes as f64 / actual
    }

    /// Dense-baseline / actual ratio of the steady-state traffic alone
    /// (refresh excluded): structurally `min(m,n) / r` per matrix.
    pub fn steady_reduction_vs_dense(&self) -> f64 {
        if self.lowrank_bytes == 0 {
            return f64::NAN;
        }
        self.dense_equiv_bytes as f64 / self.lowrank_bytes as f64
    }

    pub fn merge(&mut self, other: &CommStats) {
        self.lowrank_bytes += other.lowrank_bytes;
        self.refresh_dense_bytes += other.refresh_dense_bytes;
        self.other_dense_bytes += other.other_dense_bytes;
        self.dense_equiv_bytes += other.dense_equiv_bytes;
        self.control_bytes += other.control_bytes;
        self.lowrank_reduces += other.lowrank_reduces;
        self.dense_reduces += other.dense_reduces;
        self.checksummed_payloads += other.checksummed_payloads;
        self.checksum_failures += other.checksum_failures;
        self.dropped_payloads += other.dropped_payloads;
        self.duplicate_payloads += other.duplicate_payloads;
        self.delayed_payloads += other.delayed_payloads;
        self.retries += other.retries;
        self.retry_bytes += other.retry_bytes;
        self.backoff_units += other.backoff_units;
    }

    /// Copy of `self` with every fault/retry counter zeroed — the part
    /// of the accounting that must match a fault-free run byte-for-byte.
    pub fn without_fault_counters(&self) -> CommStats {
        let mut c = self.clone();
        c.checksum_failures = 0;
        c.dropped_payloads = 0;
        c.duplicate_payloads = 0;
        c.delayed_payloads = 0;
        c.retries = 0;
        c.retry_bytes = 0;
        c.backoff_units = 0;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn random_slots(n: usize, len: usize, seed: u64) -> Vec<Matrix> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| Matrix::randn(1, len, 1.0, &mut rng)).collect()
    }

    #[test]
    fn owner_blocks_are_contiguous_and_cross_edges_count_workers() {
        let t = Topology::new(8, 4);
        assert_eq!((0..8).map(|s| t.owner(s)).collect::<Vec<_>>(), [0, 0, 1, 1, 2, 2, 3, 3]);
        assert_eq!(t.cross_edges(), 3);
        assert_eq!(Topology::new(4, 1).cross_edges(), 0);
        assert_eq!(Topology::new(4, 4).cross_edges(), 3);
        assert_eq!(Topology::new(6, 3).cross_edges(), 2);
    }

    #[test]
    fn tree_sum_is_worker_count_invariant() {
        // The reduced value must depend only on the shard count: reduce
        // the same slots under every divisor worker count and compare
        // bit-for-bit.
        for shards in [1usize, 2, 4, 6, 8] {
            let reference = {
                let mut slots = random_slots(shards, 37, 11);
                tree_reduce_with(&mut slots, |m| &mut m.data[..], &Topology::new(shards, 1));
                slots[0].data.clone()
            };
            for workers in 1..=shards {
                if shards % workers != 0 {
                    continue;
                }
                let mut slots = random_slots(shards, 37, 11);
                let topo = Topology::new(shards, workers);
                let edges = tree_reduce_with(&mut slots, |m| &mut m.data[..], &topo);
                assert_eq!(slots[0].data, reference, "shards={shards} workers={workers}");
                assert_eq!(edges, topo.cross_edges(), "edge census");
            }
        }
    }

    #[test]
    fn tree_sum_matches_f32_tree_arithmetic() {
        // 4 slots: ((s0+s1) + (s2+s3)), elementwise in f32.
        let mut slots = random_slots(4, 9, 12);
        let expect: Vec<f32> = (0..9)
            .map(|i| {
                (slots[0].data[i] + slots[1].data[i]) + (slots[2].data[i] + slots[3].data[i])
            })
            .collect();
        tree_reduce_with(&mut slots, |m| &mut m.data[..], &Topology::new(4, 2));
        assert_eq!(slots[0].data, expect);
    }

    #[test]
    fn byte_accounting_ratios() {
        let mut c = CommStats::default();
        // 10 steady steps of a 128×128 matrix at rank 16, 3 cross edges
        for _ in 0..10 {
            c.record_lowrank(3, 16 * 128 * 4, 128 * 128 * 4);
        }
        assert!((c.steady_reduction_vs_dense() - 8.0).abs() < 1e-12);
        // one dense refresh drags the end-to-end ratio below 8
        c.record_refresh_dense(3, 128 * 128 * 4);
        assert!(c.reduction_vs_dense() < 8.0);
        assert!(c.reduction_vs_dense() > 1.0);
        let t = c.total_bytes();
        c.record_votes(3, 4);
        assert_eq!(c.total_bytes(), t + 15);
    }

    #[test]
    #[should_panic]
    fn mismatched_topology_is_rejected() {
        let mut slots = random_slots(4, 3, 13);
        tree_reduce_with(&mut slots, |m| &mut m.data[..], &Topology::new(8, 2));
    }

    #[test]
    fn checksum_detects_single_bit_flips() {
        let mut rng = Rng::new(21);
        let m = Matrix::randn(1, 64, 1.0, &mut rng);
        let clean = checksum(&m.data, CHECKSUM_SEED);
        for i in 0..m.data.len() {
            for bit in [0u32, 7, 15, 23, 31] {
                let mut d = m.data.clone();
                d[i] = f32::from_bits(d[i].to_bits() ^ (1 << bit));
                assert_ne!(checksum(&d, CHECKSUM_SEED), clean, "word {i} bit {bit}");
            }
        }
        // Length is part of the hash (truncation is detected too).
        assert_ne!(checksum(&m.data[..63], CHECKSUM_SEED), clean);
    }

    #[test]
    fn hardened_reduce_matches_plain_reduce_without_faults() {
        let mut a = random_slots(8, 37, 14);
        let mut b = random_slots(8, 37, 14);
        let topo = Topology::new(8, 4);
        let plain = tree_reduce_with(&mut a, |m| &mut m.data[..], &topo);
        let mut stats = CommStats::default();
        let hard =
            tree_reduce_hardened(&mut b, |m| &mut m.data[..], &topo, None, &mut stats).unwrap();
        assert_eq!(plain, hard);
        assert_eq!(a[0].data, b[0].data);
        assert_eq!(stats.checksummed_payloads, topo.cross_edges());
        assert_eq!(stats.retries, 0);
    }

    #[test]
    fn hardened_reduce_recovers_bit_exactly_from_injected_faults() {
        use crate::faults::{FaultInjector, FaultPlan};
        let topo = Topology::new(4, 4);
        let reference = {
            let mut slots = random_slots(4, 19, 15);
            tree_reduce_with(&mut slots, |m| &mut m.data[..], &topo);
            slots[0].data.clone()
        };
        // One fault of each payload kind, each aimed at a distinct
        // transfer of "step" 1 (three cross edges -> reuse step 2).
        let plan = FaultPlan::parse("flip@1#0,drop@1#1,dup@1#2,delay@2#0", 9).unwrap();
        let mut inj = FaultInjector::new(plan);
        let mut stats = CommStats::default();
        for step in 1..=2u64 {
            inj.begin_step(step);
            let mut slots = random_slots(4, 19, 15);
            tree_reduce_hardened(&mut slots, |m| &mut m.data[..], &topo, Some(&mut inj), &mut stats)
                .unwrap();
            assert_eq!(slots[0].data, reference, "step {step}");
        }
        assert_eq!(stats.checksum_failures, 1);
        assert_eq!(stats.dropped_payloads, 1);
        assert_eq!(stats.duplicate_payloads, 1);
        assert_eq!(stats.delayed_payloads, 1);
        assert_eq!(stats.retries, 2); // one resend each for the flip and the drop
        assert!(stats.backoff_units >= 3);
        assert_eq!(inj.stats.bit_flips, 1);
        // Payload accounting (the caller-side byte counters) carries no
        // fault residue: zeroing the fault counters matches a clean run.
        let mut clean = CommStats::default();
        let mut slots = random_slots(4, 19, 15);
        tree_reduce_hardened(&mut slots, |m| &mut m.data[..], &topo, None, &mut clean).unwrap();
        let mut slots = random_slots(4, 19, 15);
        tree_reduce_hardened(&mut slots, |m| &mut m.data[..], &topo, None, &mut clean).unwrap();
        assert_eq!(stats.without_fault_counters(), clean);
    }

    #[test]
    fn vote_exchange_is_checksummed_and_accounted() {
        let topo = Topology::new(4, 2);
        let votes = [7.0f32, 0.0, 7.0, 0.0, 7.0]; // 4 shards + agreed slot
        let mut stats = CommStats::default();
        exchange_votes(&votes, &topo, None, &mut stats).unwrap();
        assert_eq!(stats.checksummed_payloads, topo.cross_edges());
        assert_eq!(stats.control_bytes, topo.cross_edges() * 4 * votes.len() as u64);
        assert_eq!(stats.retries, 0);
        // An injected flip on the vote payload is caught and resent.
        use crate::faults::{FaultInjector, FaultPlan};
        let mut inj = FaultInjector::new(FaultPlan::parse("flip@1#0", 5).unwrap());
        inj.begin_step(1);
        let mut faulty = CommStats::default();
        exchange_votes(&votes, &topo, Some(&mut inj), &mut faulty).unwrap();
        assert_eq!(faulty.checksum_failures, 1);
        assert_eq!(faulty.retries, 1);
        // Payload accounting matches the clean exchange byte-for-byte.
        assert_eq!(faulty.without_fault_counters(), stats);
        // A single-worker topology has no wire edges and costs nothing.
        let mut local = CommStats::default();
        exchange_votes(&votes, &Topology::new(4, 1), None, &mut local).unwrap();
        assert_eq!(local, CommStats::default());
    }

    #[test]
    fn byte_checksum_detects_single_bit_flips() {
        let mut rng = Rng::new(31);
        let data: Vec<u8> = (0..67).map(|_| rng.below(256) as u8).collect();
        let clean = checksum_bytes(&data, CHECKSUM_SEED);
        for i in 0..data.len() {
            for bit in [0u32, 3, 7] {
                let mut d = data.clone();
                d[i] ^= 1u8 << bit;
                assert_ne!(checksum_bytes(&d, CHECKSUM_SEED), clean, "byte {i} bit {bit}");
            }
        }
        // truncation is detected (length is folded into the hash)
        assert_ne!(checksum_bytes(&data[..66], CHECKSUM_SEED), clean);
    }

    #[test]
    fn quantized_reduce_is_worker_count_invariant() {
        use crate::quant::{Codec, QuantDtype};
        for codec in [Codec::new(QuantDtype::Bf16, 64), Codec::new(QuantDtype::Int8, 16)] {
            for shards in [2usize, 4, 8] {
                let reference = {
                    let mut slots = random_slots(shards, 37, 41);
                    let mut stats = CommStats::default();
                    tree_reduce_quantized(
                        &mut slots,
                        |m| &mut m.data[..],
                        &Topology::new(shards, 1),
                        codec,
                        None,
                        &mut stats,
                    )
                    .unwrap();
                    slots[0].data.clone()
                };
                for workers in 1..=shards {
                    if shards % workers != 0 {
                        continue;
                    }
                    let mut slots = random_slots(shards, 37, 41);
                    let topo = Topology::new(shards, workers);
                    let mut stats = CommStats::default();
                    let edges = tree_reduce_quantized(
                        &mut slots,
                        |m| &mut m.data[..],
                        &topo,
                        codec,
                        None,
                        &mut stats,
                    )
                    .unwrap();
                    assert_eq!(
                        slots[0].data, reference,
                        "{codec:?} shards={shards} workers={workers}"
                    );
                    assert_eq!(edges, topo.cross_edges());
                    assert_eq!(stats.checksummed_payloads, topo.cross_edges());
                }
            }
        }
    }

    #[test]
    fn quantized_reduce_with_f32_codec_is_the_hardened_path() {
        use crate::quant::{Codec, QuantDtype};
        let topo = Topology::new(8, 4);
        let mut a = random_slots(8, 23, 43);
        let mut b = random_slots(8, 23, 43);
        let mut sa = CommStats::default();
        let mut sb = CommStats::default();
        tree_reduce_hardened(&mut a, |m| &mut m.data[..], &topo, None, &mut sa).unwrap();
        tree_reduce_quantized(
            &mut b,
            |m| &mut m.data[..],
            &topo,
            Codec::new(QuantDtype::F32, 64),
            None,
            &mut sb,
        )
        .unwrap();
        assert_eq!(a[0].data, b[0].data);
        assert_eq!(sa, sb);
    }

    #[test]
    fn quantized_reduce_recovers_bit_exactly_from_injected_faults() {
        use crate::faults::{FaultInjector, FaultPlan};
        use crate::quant::{Codec, QuantDtype};
        let topo = Topology::new(4, 4);
        let codec = Codec::new(QuantDtype::Int8, 16);
        let reference = {
            let mut slots = random_slots(4, 19, 45);
            let mut stats = CommStats::default();
            tree_reduce_quantized(&mut slots, |m| &mut m.data[..], &topo, codec, None, &mut stats)
                .unwrap();
            slots[0].data.clone()
        };
        let plan = FaultPlan::parse("flip@1#0,drop@1#1,dup@1#2,delay@2#0", 9).unwrap();
        let mut inj = FaultInjector::new(plan);
        let mut stats = CommStats::default();
        for step in 1..=2u64 {
            inj.begin_step(step);
            let mut slots = random_slots(4, 19, 45);
            tree_reduce_quantized(
                &mut slots,
                |m| &mut m.data[..],
                &topo,
                codec,
                Some(&mut inj),
                &mut stats,
            )
            .unwrap();
            assert_eq!(slots[0].data, reference, "step {step}");
        }
        assert_eq!(stats.checksum_failures, 1);
        assert_eq!(stats.dropped_payloads, 1);
        assert_eq!(stats.retries, 2);
    }

    #[test]
    fn quantized_reduce_falls_back_to_f32_on_non_finite_payloads() {
        use crate::quant::{Codec, QuantDtype};
        let topo = Topology::new(4, 2);
        let codec = Codec::new(QuantDtype::Int8, 16);
        let mut slots = random_slots(4, 9, 47);
        slots[2].data[3] = f32::NAN;
        let mut stats = CommStats::default();
        tree_reduce_quantized(&mut slots, |m| &mut m.data[..], &topo, codec, None, &mut stats)
            .unwrap();
        // the NaN propagates into the reduced slot (engine guards catch it)
        assert!(slots[0].data[3].is_nan());
        assert!(slots[0].data[0].is_finite());
    }
}
