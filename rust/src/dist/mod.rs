//! Distributed data-parallel runtime with low-rank gradient exchange.
//!
//! Lotus keeps optimizer state and gradient traffic in an r×n subspace;
//! this module exploits the same projection to make N-worker data
//! parallelism nearly free: workers exchange only *projected* gradients
//! (an (min(m,n)/r)× smaller all-reduce payload than dense DDP), and
//! adaptive subspace switching becomes a **consensus** operation — shards
//! vote with their local displacement criterion, and a quorum triggers
//! one lockstep refresh from the all-reduced dense gradient so every
//! replica holds a bit-identical projector.
//!
//! Three sub-modules:
//!
//! * [`comm`] — shard-indexed stride-doubling tree all-reduce with byte
//!   accounting ([`CommStats`]; analytic twin in
//!   [`crate::memcount::allreduce_layer_bytes`]).
//! * [`consensus`] — quorum voting over per-shard switch decisions.
//! * [`engine`] — [`DistTrainer`], the N-worker training loop layered on
//!   [`crate::runtime::pool`].
//!
//! **Determinism.** Everything that touches arithmetic is indexed by
//! *canonical shard*, never by worker: token streams, gradient
//! reduction order, policy replicas, consensus votes, refresh RNG
//! streams. The worker count only assigns shards to pool threads, so an
//! N-worker run is bit-identical to the single-worker run on the same
//! total batch — at any `LOTUS_THREADS` setting (`rust/tests/dist.rs`,
//! CI matrix).
//!
//! **Fault tolerance** (PR 6). Cross-worker payloads are checksummed and
//! retried ([`comm::tree_reduce_hardened`]), a dead worker is re-sharded
//! away in memory ([`DistTrainer::declare_dead`]), and numerical guards
//! (NaN skip-step, windowed loss-spike rollback) keep a faulted run
//! bit-identical to its fault-free oracle — driven by the seeded
//! schedules in [`crate::faults`] and asserted in `rust/tests/faults.rs`.
//!
//! **Quantized wire** (PR 8). With `--wire-dtype bf16|int8` the tree
//! all-reduce ships codec-encoded payloads ([`comm::tree_reduce_quantized`]):
//! checksums cover the quantized bytes, `CommStats` charges the encoded
//! size, and the uniform per-edge encode→decode keeps worker-count
//! invariance (`rust/tests/quant.rs`, `BENCH_quant.json`).

pub mod comm;
pub mod consensus;
pub mod engine;

pub use comm::{
    checksum, checksum_bytes, tree_reduce_hardened, tree_reduce_quantized, CommError, CommStats,
    Topology,
};
pub use consensus::{ConsensusCfg, ConsensusStats};
pub use engine::{DistCfg, DistReport, DistTrainer, StepOutcome, MATS_PER_LAYER};
