//! Subspace-switch consensus across data shards.
//!
//! In single-worker Lotus the switching policy observes the projected
//! gradient of the whole batch; in data-parallel training each shard
//! only sees its own (noisier) slice. Rather than reduce the gradient
//! first and vote centrally, every shard runs a *local* policy replica
//! on its local projected gradient and casts a vote; a quorum of switch
//! votes triggers one lockstep refresh from the **all-reduced** dense
//! gradient, so every replica fits — with RNG streams that advanced in
//! lockstep — the bit-identical projector. Votes are indexed by shard,
//! not worker, so the decision (like the reduction tree in
//! [`super::comm`]) is invariant to the worker count.

use crate::subspace::{Decision, SwitchReason};

/// Quorum configuration: the fraction of shard votes required to trigger
/// a switch (0 < quorum ≤ 1; 0.5 = simple majority, 1.0 = unanimity).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConsensusCfg {
    pub quorum: f64,
}

impl Default for ConsensusCfg {
    fn default() -> Self {
        ConsensusCfg { quorum: 0.5 }
    }
}

impl ConsensusCfg {
    /// Votes needed among `shards` voters (at least 1).
    pub fn needed(&self, shards: usize) -> usize {
        assert!(self.quorum > 0.0 && self.quorum <= 1.0, "quorum must be in (0, 1]");
        ((self.quorum * shards as f64).ceil() as usize).clamp(1, shards)
    }
}

/// Deterministic priority for reporting the consensus reason when votes
/// disagree on *why* to switch (Init always wins: an unfitted replica
/// forces a lockstep fit).
fn reason_priority(r: SwitchReason) -> u8 {
    match r {
        SwitchReason::Init => 3,
        SwitchReason::Displacement => 2,
        SwitchReason::PathEfficiency => 1,
        SwitchReason::Interval => 0,
    }
}

/// Fold shard votes into a switch decision. Returns the consensus reason
/// when at least `cfg.needed(votes.len())` shards voted to switch (any
/// Init vote triggers unconditionally), `None` otherwise.
pub fn decide(votes: &[Decision], cfg: &ConsensusCfg) -> Option<SwitchReason> {
    assert!(!votes.is_empty(), "consensus over zero shards");
    let mut best: Option<SwitchReason> = None;
    let mut switching = 0usize;
    for v in votes {
        if let Decision::Switch(r) = v {
            switching += 1;
            best = match best {
                Some(b) if reason_priority(b) >= reason_priority(*r) => Some(b),
                _ => Some(*r),
            };
        }
    }
    match best {
        Some(SwitchReason::Init) => Some(SwitchReason::Init),
        Some(r) if switching >= cfg.needed(votes.len()) => Some(r),
        _ => None,
    }
}

/// Aggregate consensus telemetry across matrices and steps.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConsensusStats {
    /// Voting rounds held (one per projected matrix per step once the
    /// subspace exists; init fits are not rounds).
    pub rounds: u64,
    /// Rounds that reached quorum and triggered a refresh.
    pub triggered: u64,
    /// Rounds where every shard voted the same way.
    pub unanimous: u64,
    /// Total votes cast / votes for switching.
    pub votes: u64,
    pub votes_for_switch: u64,
}

impl ConsensusStats {
    pub fn record_round(&mut self, votes: &[Decision], triggered: bool) {
        self.rounds += 1;
        let switching = votes.iter().filter(|v| matches!(v, Decision::Switch(_))).count() as u64;
        self.votes += votes.len() as u64;
        self.votes_for_switch += switching;
        if switching == 0 || switching == votes.len() as u64 {
            self.unanimous += 1;
        }
        if triggered {
            self.triggered += 1;
        }
    }

    pub fn merge(&mut self, other: &ConsensusStats) {
        self.rounds += other.rounds;
        self.triggered += other.triggered;
        self.unanimous += other.unanimous;
        self.votes += other.votes;
        self.votes_for_switch += other.votes_for_switch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const K: Decision = Decision::Keep;
    const D: Decision = Decision::Switch(SwitchReason::Displacement);
    const I: Decision = Decision::Switch(SwitchReason::Interval);

    #[test]
    fn majority_triggers_minority_does_not() {
        let cfg = ConsensusCfg::default();
        assert_eq!(decide(&[D, D, K, K], &cfg), Some(SwitchReason::Displacement));
        assert_eq!(decide(&[D, K, K, K], &cfg), None);
        assert_eq!(decide(&[K, K, K, K], &cfg), None);
        assert_eq!(decide(&[D], &cfg), Some(SwitchReason::Displacement));
    }

    #[test]
    fn unanimity_quorum_requires_every_shard() {
        let cfg = ConsensusCfg { quorum: 1.0 };
        assert_eq!(decide(&[D, D, D, K], &cfg), None);
        assert_eq!(decide(&[D, D, D, D], &cfg), Some(SwitchReason::Displacement));
    }

    #[test]
    fn init_vote_overrides_quorum() {
        let cfg = ConsensusCfg { quorum: 1.0 };
        let votes = [Decision::Switch(SwitchReason::Init), K, K, K];
        assert_eq!(decide(&votes, &cfg), Some(SwitchReason::Init));
    }

    #[test]
    fn reason_priority_is_deterministic() {
        let cfg = ConsensusCfg::default();
        assert_eq!(decide(&[I, D, D, I], &cfg), Some(SwitchReason::Displacement));
        assert_eq!(decide(&[I, I, I, I], &cfg), Some(SwitchReason::Interval));
    }

    #[test]
    fn needed_rounds_up() {
        let cfg = ConsensusCfg { quorum: 0.5 };
        assert_eq!(cfg.needed(4), 2);
        assert_eq!(cfg.needed(5), 3);
        assert_eq!(cfg.needed(1), 1);
        let strict = ConsensusCfg { quorum: 0.75 };
        assert_eq!(strict.needed(4), 3);
    }

    #[test]
    fn stats_track_unanimity() {
        let mut s = ConsensusStats::default();
        s.record_round(&[K, K], false);
        s.record_round(&[D, D], true);
        s.record_round(&[D, K], false);
        assert_eq!(s.rounds, 3);
        assert_eq!(s.triggered, 1);
        assert_eq!(s.unanimous, 2);
        assert_eq!(s.votes, 6);
        assert_eq!(s.votes_for_switch, 3);
    }
}
