//! Subspace-switch consensus across data shards.
//!
//! In single-worker Lotus the switching policy observes the projected
//! gradient of the whole batch; in data-parallel training each shard
//! only sees its own (noisier) slice. Rather than reduce the gradient
//! first and vote centrally, every shard runs a *local* policy replica
//! on its local projected gradient and casts a vote; a quorum of switch
//! votes triggers one lockstep refresh from the **all-reduced** dense
//! gradient, so every replica fits — with RNG streams that advanced in
//! lockstep — the bit-identical projector. Votes are indexed by shard,
//! not worker, so the decision (like the reduction tree in
//! [`super::comm`]) is invariant to the worker count.

use crate::subspace::{Decision, SwitchReason};

/// Quorum configuration: the fraction of shard votes required to trigger
/// a switch (0 < quorum ≤ 1; 0.5 = simple majority, 1.0 = unanimity).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConsensusCfg {
    pub quorum: f64,
}

impl Default for ConsensusCfg {
    fn default() -> Self {
        ConsensusCfg { quorum: 0.5 }
    }
}

impl ConsensusCfg {
    /// Votes needed among `shards` voters (at least 1).
    pub fn needed(&self, shards: usize) -> usize {
        assert!(self.quorum > 0.0 && self.quorum <= 1.0, "quorum must be in (0, 1]");
        ((self.quorum * shards as f64).ceil() as usize).clamp(1, shards)
    }
}

/// Deterministic priority for reporting the consensus reason when votes
/// disagree on *why* to switch (Init always wins: an unfitted replica
/// forces a lockstep fit).
fn reason_priority(r: SwitchReason) -> u8 {
    match r {
        SwitchReason::Init => 3,
        SwitchReason::Displacement => 2,
        SwitchReason::PathEfficiency => 1,
        SwitchReason::Interval => 0,
    }
}

/// Fold shard votes into a switch decision. Returns the consensus reason
/// when at least `cfg.needed(votes.len())` shards voted to switch (any
/// Init vote triggers unconditionally), `None` otherwise.
pub fn decide(votes: &[Decision], cfg: &ConsensusCfg) -> Option<SwitchReason> {
    assert!(!votes.is_empty(), "consensus over zero shards");
    let mut best: Option<SwitchReason> = None;
    let mut switching = 0usize;
    for v in votes {
        if let Decision::Switch(r) = v {
            switching += 1;
            best = match best {
                Some(b) if reason_priority(b) >= reason_priority(*r) => Some(b),
                _ => Some(*r),
            };
        }
    }
    match best {
        Some(SwitchReason::Init) => Some(SwitchReason::Init),
        Some(r) if switching >= cfg.needed(votes.len()) => Some(r),
        _ => None,
    }
}

/// One shard's vote in a rollback recovery round: `None` = the shard
/// sees a healthy trajectory, `Some(step)` = the shard's spike detector
/// or NaN guard fired and it proposes restoring from a checkpoint at or
/// before `step`.
pub type RollbackVote = Option<u64>;

/// Outcome of a rollback voting round over shard-indexed votes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RollbackDecision {
    /// Shards that proposed a restore.
    pub proposals: usize,
    /// Total voters (the canonical shard count).
    pub voters: usize,
    /// Votes required for quorum ([`ConsensusCfg::needed`]).
    pub needed: usize,
    /// Tightest proposed bound: the minimum restore step among
    /// proposals (present whenever `proposals > 0`).
    pub min_step: Option<u64>,
    /// Quorum reached — every replica restores the newest checkpoint at
    /// or before `min_step`, in lockstep.
    pub rollback: bool,
}

/// Fold shard-indexed rollback votes into a restore decision. Reuses
/// the displacement-vote quorum rule: at least `cfg.needed(voters)`
/// restore proposals commit a rollback; fewer are outvoted and the run
/// continues. The agreed bound is the *minimum* proposed step, so the
/// restore target can never be newer than what any firing replica saw
/// as its last good step.
pub fn decide_rollback(votes: &[RollbackVote], cfg: &ConsensusCfg) -> RollbackDecision {
    assert!(!votes.is_empty(), "rollback consensus over zero shards");
    let mut proposals = 0usize;
    let mut min_step: Option<u64> = None;
    for v in votes {
        if let Some(s) = v {
            proposals += 1;
            min_step = Some(min_step.map_or(*s, |m| m.min(*s)));
        }
    }
    let needed = cfg.needed(votes.len());
    RollbackDecision {
        proposals,
        voters: votes.len(),
        needed,
        min_step,
        rollback: proposals >= needed,
    }
}

/// The newest retained checkpoint at or before the agreed bound
/// (`history` holds `(step, path)` in ascending step order).
pub fn agreed_checkpoint(history: &[(u64, String)], bound: u64) -> Option<&(u64, String)> {
    history.iter().rev().find(|(s, _)| *s <= bound)
}

/// Aggregate rollback-consensus telemetry across recovery rounds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RollbackStats {
    /// Recovery voting rounds held.
    pub rounds: u64,
    /// Rounds that reached quorum and restored a checkpoint.
    pub committed: u64,
    /// Rounds where a minority proposal was outvoted (no rollback).
    pub outvoted: u64,
    /// Restore proposals cast across all rounds.
    pub proposals: u64,
}

impl RollbackStats {
    /// Record one round: `restored` is whether a checkpoint restore was
    /// actually executed (quorum can be reached with no retained
    /// checkpoint or an exhausted rollback budget — neither committed
    /// nor outvoted).
    pub fn record_round(&mut self, d: &RollbackDecision, restored: bool) {
        self.rounds += 1;
        self.proposals += d.proposals as u64;
        if restored {
            self.committed += 1;
        } else if !d.rollback {
            self.outvoted += 1;
        }
    }

    pub fn merge(&mut self, other: &RollbackStats) {
        self.rounds += other.rounds;
        self.committed += other.committed;
        self.outvoted += other.outvoted;
        self.proposals += other.proposals;
    }
}

/// Aggregate consensus telemetry across matrices and steps.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConsensusStats {
    /// Voting rounds held (one per projected matrix per step once the
    /// subspace exists; init fits are not rounds).
    pub rounds: u64,
    /// Rounds that reached quorum and triggered a refresh.
    pub triggered: u64,
    /// Rounds where every shard voted the same way.
    pub unanimous: u64,
    /// Total votes cast / votes for switching.
    pub votes: u64,
    pub votes_for_switch: u64,
}

impl ConsensusStats {
    pub fn record_round(&mut self, votes: &[Decision], triggered: bool) {
        self.rounds += 1;
        let switching = votes.iter().filter(|v| matches!(v, Decision::Switch(_))).count() as u64;
        self.votes += votes.len() as u64;
        self.votes_for_switch += switching;
        if switching == 0 || switching == votes.len() as u64 {
            self.unanimous += 1;
        }
        if triggered {
            self.triggered += 1;
        }
    }

    pub fn merge(&mut self, other: &ConsensusStats) {
        self.rounds += other.rounds;
        self.triggered += other.triggered;
        self.unanimous += other.unanimous;
        self.votes += other.votes;
        self.votes_for_switch += other.votes_for_switch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const K: Decision = Decision::Keep;
    const D: Decision = Decision::Switch(SwitchReason::Displacement);
    const I: Decision = Decision::Switch(SwitchReason::Interval);

    #[test]
    fn majority_triggers_minority_does_not() {
        let cfg = ConsensusCfg::default();
        assert_eq!(decide(&[D, D, K, K], &cfg), Some(SwitchReason::Displacement));
        assert_eq!(decide(&[D, K, K, K], &cfg), None);
        assert_eq!(decide(&[K, K, K, K], &cfg), None);
        assert_eq!(decide(&[D], &cfg), Some(SwitchReason::Displacement));
    }

    #[test]
    fn unanimity_quorum_requires_every_shard() {
        let cfg = ConsensusCfg { quorum: 1.0 };
        assert_eq!(decide(&[D, D, D, K], &cfg), None);
        assert_eq!(decide(&[D, D, D, D], &cfg), Some(SwitchReason::Displacement));
    }

    #[test]
    fn init_vote_overrides_quorum() {
        let cfg = ConsensusCfg { quorum: 1.0 };
        let votes = [Decision::Switch(SwitchReason::Init), K, K, K];
        assert_eq!(decide(&votes, &cfg), Some(SwitchReason::Init));
    }

    #[test]
    fn reason_priority_is_deterministic() {
        let cfg = ConsensusCfg::default();
        assert_eq!(decide(&[I, D, D, I], &cfg), Some(SwitchReason::Displacement));
        assert_eq!(decide(&[I, I, I, I], &cfg), Some(SwitchReason::Interval));
    }

    #[test]
    fn needed_rounds_up() {
        let cfg = ConsensusCfg { quorum: 0.5 };
        assert_eq!(cfg.needed(4), 2);
        assert_eq!(cfg.needed(5), 3);
        assert_eq!(cfg.needed(1), 1);
        let strict = ConsensusCfg { quorum: 0.75 };
        assert_eq!(strict.needed(4), 3);
    }

    #[test]
    fn rollback_majority_commits_minority_is_outvoted() {
        let cfg = ConsensusCfg::default();
        let d = decide_rollback(&[Some(6), Some(6), None, None], &cfg);
        assert!(d.rollback);
        assert_eq!(d.proposals, 2);
        assert_eq!(d.needed, 2);
        assert_eq!(d.min_step, Some(6));
        let lone = decide_rollback(&[Some(6), None, None, None], &cfg);
        assert!(!lone.rollback, "a lone false positive is outvoted");
        assert_eq!(lone.min_step, Some(6));
        let quiet = decide_rollback(&[None, None], &cfg);
        assert!(!quiet.rollback);
        assert_eq!(quiet.min_step, None);
    }

    #[test]
    fn rollback_bound_is_the_minimum_proposed_step() {
        let cfg = ConsensusCfg::default();
        let d = decide_rollback(&[Some(9), Some(3), Some(6), None], &cfg);
        assert!(d.rollback);
        assert_eq!(d.min_step, Some(3));
    }

    #[test]
    fn agreed_checkpoint_is_newest_at_or_before_bound() {
        let hist =
            vec![(3u64, "a".to_string()), (6, "b".to_string()), (9, "c".to_string())];
        assert_eq!(agreed_checkpoint(&hist, 10).map(|e| e.0), Some(9));
        assert_eq!(agreed_checkpoint(&hist, 9).map(|e| e.0), Some(9));
        assert_eq!(agreed_checkpoint(&hist, 8).map(|e| e.0), Some(6));
        assert_eq!(agreed_checkpoint(&hist, 3).map(|e| e.0), Some(3));
        assert_eq!(agreed_checkpoint(&hist, 2), None);
        assert_eq!(agreed_checkpoint(&[], 5), None);
    }

    #[test]
    fn rollback_stats_classify_rounds() {
        let cfg = ConsensusCfg::default();
        let mut s = RollbackStats::default();
        let committed = decide_rollback(&[Some(6), Some(6), None, None], &cfg);
        s.record_round(&committed, true);
        let outvoted = decide_rollback(&[Some(6), None, None, None], &cfg);
        s.record_round(&outvoted, false);
        // quorum reached but nothing to restore (no checkpoint/budget)
        let starved = decide_rollback(&[Some(0), Some(0)], &cfg);
        s.record_round(&starved, false);
        assert_eq!(s.rounds, 3);
        assert_eq!(s.committed, 1);
        assert_eq!(s.outvoted, 1);
        assert_eq!(s.proposals, 5);
    }

    #[test]
    fn stats_track_unanimity() {
        let mut s = ConsensusStats::default();
        s.record_round(&[K, K], false);
        s.record_round(&[D, D], true);
        s.record_round(&[D, K], false);
        assert_eq!(s.rounds, 3);
        assert_eq!(s.triggered, 1);
        assert_eq!(s.unanimous, 2);
        assert_eq!(s.votes, 6);
        assert_eq!(s.votes_for_switch, 3);
    }
}
