//! Deterministic N-worker data-parallel training engine.
//!
//! The engine layers data parallelism on the PR 1 substrate
//! ([`crate::runtime::pool`]): the global batch is split into
//! **canonical shards** ([`crate::data::batch::ShardSampler`]), each with
//! its own token stream, gradient buffer and switching-policy replica.
//! `--workers N` only chooses how many pool workers *execute* those
//! shards (contiguous blocks, like `LOTUS_THREADS` for row bands); the
//! decomposition, the stride-doubling reduction tree ([`super::comm`])
//! and the shard-indexed consensus votes ([`super::consensus`]) all
//! depend on the shard count alone. An N=4 run is therefore bit-identical
//! to an N=1 run on the same total batch — asserted in
//! `rust/tests/dist.rs` and `benches/dist.rs`.
//!
//! Per step, for every projected matrix:
//!
//! 1. each shard computes a local full-rank gradient (fwd/bwd fan-out);
//! 2. each shard projects it with the **shared** subspace and votes with
//!    its local displacement criterion (Algorithm 1 on shard data);
//! 3. on quorum, one lockstep refresh fits the projector from the
//!    all-reduced dense gradient — per-matrix RNG streams advance in
//!    lockstep, so all replicas hold bit-identical projectors;
//! 4. the tree all-reduce exchanges only the r×n *projected* gradient
//!    (the m×n dense gradient crosses the wire only on refresh steps);
//! 5. one canonical Adam-in-the-subspace step updates the replica.
//!
//! Tensors that are dense in every method (embedding, norm vectors, the
//! full-rank baseline's matrices) all-reduce densely; every byte is
//! accounted in [`CommStats`] against a dense-gradient baseline.

use super::comm::{exchange_votes, tree_reduce_quantized, CommStats, Topology};
use super::consensus::{
    agreed_checkpoint, decide, decide_rollback, ConsensusCfg, ConsensusStats, RollbackStats,
    RollbackVote,
};
use crate::quant::Codec;
use crate::data::batch::{ShardSampler, SyncBatcher};
use crate::data::corpus::CorpusGen;
use crate::faults::{
    FaultInjector, FaultKind, FaultPlan, FaultStats, GuardCfg, RecoveryStats, SpikeDetector,
};
use crate::optim::registry;
use crate::optim::{Adam, OptState, Optimizer, StepEvent};
use crate::runtime::pool::Pool;
use crate::sim::model::{Gradients, Params, SimModel};
use crate::sim::trainer::{
    dense_tail_update, grad_full_norm, layer_matrix_shapes, mat_seed, scale_gradients, Method,
    SimRunCfg,
};
use crate::subspace::{
    Decision, FixedInterval, LotusAdaSS, Observation, PolicyState, SubspaceStats, SwitchPolicy,
    SwitchReason,
};
use crate::telemetry::{self, diag, span, SpanKind, SPAN_KINDS};
use crate::tensor::Matrix;
use crate::train::checkpoint::{self, push_u64, read_u64_limbs};
use crate::util::json::JsonValue;
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};

/// Projected matrices per transformer layer, in the canonical order the
/// sim trainer uses: wq, wk, wv, wo, w1, w3, w2.
pub const MATS_PER_LAYER: usize = 7;

/// Distributed-run shape: executing workers, canonical data shards, and
/// the consensus quorum.
///
/// `shards == 0` means "one shard per worker". The shard decomposition —
/// not the worker count — fixes the arithmetic (gradient sums, consensus
/// votes), so runs comparing worker counts must pin `shards`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DistCfg {
    pub workers: usize,
    pub shards: usize,
    pub quorum: f64,
}

impl Default for DistCfg {
    fn default() -> Self {
        DistCfg { workers: 1, shards: 0, quorum: 0.5 }
    }
}

impl DistCfg {
    pub fn with_workers(workers: usize) -> DistCfg {
        DistCfg { workers, ..Default::default() }
    }

    /// The canonical shard count (`shards`, or `workers` when unset).
    pub fn shard_count(&self) -> usize {
        if self.shards == 0 {
            self.workers
        } else {
            self.shards
        }
    }

    /// True when this config asks for the distributed engine at all.
    pub fn is_distributed(&self) -> bool {
        self.workers > 1 || self.shard_count() > 1
    }

    /// Structural constraints (worker blocks must tile the shards, the
    /// shards must tile the global batch).
    pub fn validate(&self, batch: usize) -> std::result::Result<(), String> {
        if self.workers == 0 {
            return Err("dist.workers must be >= 1".into());
        }
        let s = self.shard_count();
        if s < self.workers || s % self.workers != 0 {
            return Err(format!(
                "dist.shards ({s}) must be a multiple of dist.workers ({})",
                self.workers
            ));
        }
        if batch == 0 || batch % s != 0 {
            return Err(format!("batch ({batch}) must be divisible by dist.shards ({s})"));
        }
        if !(self.quorum > 0.0 && self.quorum <= 1.0) {
            return Err(format!("dist.quorum ({}) must be in (0, 1]", self.quorum));
        }
        Ok(())
    }
}

fn grad_mat(g: &Gradients, mi: usize) -> &Matrix {
    let lg = &g.layers[mi / MATS_PER_LAYER];
    match mi % MATS_PER_LAYER {
        0 => &lg.wq,
        1 => &lg.wk,
        2 => &lg.wv,
        3 => &lg.wo,
        4 => &lg.w1,
        5 => &lg.w3,
        6 => &lg.w2,
        _ => unreachable!(),
    }
}

fn grad_mat_mut(g: &mut Gradients, mi: usize) -> &mut Matrix {
    let lg = &mut g.layers[mi / MATS_PER_LAYER];
    match mi % MATS_PER_LAYER {
        0 => &mut lg.wq,
        1 => &mut lg.wk,
        2 => &mut lg.wv,
        3 => &mut lg.wo,
        4 => &mut lg.w1,
        5 => &mut lg.w3,
        6 => &mut lg.w2,
        _ => unreachable!(),
    }
}

fn weight_mat(p: &mut Params, mi: usize) -> &mut Matrix {
    let lp = &mut p.layers[mi / MATS_PER_LAYER];
    match mi % MATS_PER_LAYER {
        0 => &mut lp.wq,
        1 => &mut lp.wk,
        2 => &mut lp.wv,
        3 => &mut lp.wo,
        4 => &mut lp.w1,
        5 => &mut lp.w3,
        6 => &mut lp.w2,
        _ => unreachable!(),
    }
}

/// Per-shard switching-policy replica (votes on *local* gradients).
enum ShardPolicy {
    Fixed(FixedInterval),
    Lotus(LotusAdaSS),
}

impl ShardPolicy {
    fn for_method(method: Method) -> ShardPolicy {
        match method {
            Method::Lotus { gamma, eta, t_min } => {
                ShardPolicy::Lotus(LotusAdaSS::new(gamma, eta, t_min))
            }
            Method::GaLore { interval }
            | Method::RsvdFixed { interval }
            | Method::AdaRankGrad { interval, .. } => {
                ShardPolicy::Fixed(FixedInterval::new(interval))
            }
            other => unreachable!("no shard policy for {other:?}"),
        }
    }

    fn observe(&mut self, obs: &Observation<'_>) -> Decision {
        match self {
            ShardPolicy::Fixed(p) => p.observe(obs),
            ShardPolicy::Lotus(p) => p.observe(obs),
        }
    }

    fn reset(&mut self, low: &Matrix, step: u64) {
        match self {
            ShardPolicy::Fixed(p) => p.reset(low, step),
            ShardPolicy::Lotus(p) => p.reset(low, step),
        }
    }

    fn export_state(&self) -> PolicyState {
        match self {
            ShardPolicy::Fixed(p) => p.export_state(),
            ShardPolicy::Lotus(p) => p.export_state(),
        }
    }

    fn restore_state(&mut self, state: PolicyState) -> Result<(), String> {
        match self {
            ShardPolicy::Fixed(p) => p.restore_state(state),
            ShardPolicy::Lotus(p) => p.restore_state(state),
        }
    }
}

/// One shard's slice of a projected matrix: policy replica, projected
/// gradient scratch, and the latest vote.
struct ShardLocal {
    policy: ShardPolicy,
    low: Matrix,
    vote: Decision,
}

/// Per projected matrix: the canonical optimizer (identical on every
/// replica, exposing the [`crate::optim::ProjectedGradient`] capability)
/// plus one [`ShardLocal`] per shard.
struct ProjMat {
    opt: Box<dyn Optimizer>,
    locals: Vec<ShardLocal>,
    last_switch: u64,
}

/// A matrix either runs the split low-rank pipeline (the optimizer
/// exposes [`crate::optim::ProjectedGradient`]) or is driven with the
/// densely all-reduced gradient — decided once at construction by the
/// capability accessor, never by matching on the method again.
enum MatState {
    Projected(ProjMat),
    Dense(Box<dyn Optimizer>),
}

impl MatState {
    fn opt(&self) -> &dyn Optimizer {
        match self {
            MatState::Projected(pm) => pm.opt.as_ref(),
            MatState::Dense(o) => o.as_ref(),
        }
    }

    fn opt_mut(&mut self) -> &mut dyn Optimizer {
        match self {
            MatState::Projected(pm) => pm.opt.as_mut(),
            MatState::Dense(o) => o.as_mut(),
        }
    }
}

struct ShardState {
    sampler: ShardSampler,
    grads: Option<Gradients>,
    loss: f64,
}

/// Report from a distributed training run.
#[derive(Clone, Debug)]
pub struct DistReport {
    pub method: &'static str,
    pub steps: u64,
    pub workers: usize,
    pub shards: usize,
    pub final_ppl: f64,
    /// Per-step mean training loss (the bit-identity probe across worker
    /// counts).
    pub losses: Vec<f64>,
    pub loss_curve: Vec<(u64, f64)>,
    pub eval_curve: Vec<(u64, f64)>,
    pub stats: SubspaceStats,
    pub comm: CommStats,
    pub consensus: ConsensusStats,
    pub switch_steps: Vec<u64>,
    pub state_bytes: u64,
    pub total_s: f64,
    /// Recovery-layer activity: skips, rollbacks, worker deaths.
    pub recovery: RecoveryStats,
    /// Quorum rollback-consensus rounds (committed / outvoted).
    pub rollback: RollbackStats,
    /// Faults actually injected by an armed [`FaultPlan`].
    pub faults: FaultStats,
}

/// What one call to [`DistTrainer::step_once`] did.
pub enum StepOutcome {
    /// Normal step; carries the mean training loss over the total batch.
    Stepped(f64),
    /// The loss or a shard gradient was non-finite; all updates were
    /// withheld (the data cursors still advanced — skip-step semantics).
    NonFinite,
}

/// The distributed trainer: one canonical model replica, N pool workers
/// executing S canonical shards.
pub struct DistTrainer {
    pub cfg: SimRunCfg,
    pub method: Method,
    world: usize,
    n_shards: usize,
    quorum: ConsensusCfg,
    model: SimModel,
    mats: Vec<MatState>,
    emb_opt: Adam,
    norm_opts: Vec<Adam>,
    shards: Vec<ShardState>,
    eval_batcher: SyncBatcher,
    /// Reusable slots for the (rare) dense refresh reduction.
    dense_slots: Vec<Matrix>,
    pool: Pool,
    topo: Topology,
    /// Wire codec for every all-reduce payload (`--wire-dtype`): f32 is
    /// the bit-for-bit hardened path, bf16/int8 ship encoded bytes.
    wire_codec: Codec,
    pub comm: CommStats,
    pub consensus: ConsensusStats,
    stats: SubspaceStats,
    switch_steps: Vec<u64>,
    step: u64,
    eval_batches_drawn: u64,
    /// Armed fault schedule (None = fault-free run, zero overhead beyond
    /// the sender-side payload checksums).
    faults: Option<FaultInjector>,
    guard: GuardCfg,
    /// One loss-spike detector per canonical shard, each watching its
    /// shard's *local* loss — the detectors are shard-indexed like the
    /// consensus votes, so their firing pattern (and therefore every
    /// rollback decision) is invariant to the worker count.
    spikes: Vec<SpikeDetector>,
    /// Shards forced to cast a false-positive rollback vote this step
    /// (the `vote<s>@step` fault; drained each recovery round).
    forced_votes: Vec<usize>,
    /// Recovery-layer counters (skips, rollbacks, worker deaths).
    pub recovery: RecoveryStats,
    /// Quorum rollback-consensus round counters.
    pub rollback_stats: RollbackStats,
    /// EMA of the per-step max pre-clip shard norm (clip-record anomaly
    /// score). Diagnostic-only — not checkpointed.
    clip_ema: f64,
}

const DIST_META: &str = "dist/meta";

impl DistTrainer {
    pub fn new(cfg: &SimRunCfg, method: Method, dist: DistCfg, seed: u64) -> Result<DistTrainer> {
        dist.validate(cfg.batch).map_err(|e| anyhow::anyhow!("{e}"))?;
        if cfg.eval_every == 0 {
            bail!("eval_every must be positive (the train loop evals on step % eval_every)");
        }
        let n_shards = dist.shard_count();
        let per_shard_batch = cfg.batch / n_shards;
        let model = SimModel::new(cfg.model, seed);
        let d = cfg.model.d_model;
        // same construction stream as SimTrainer (adapter inits draw
        // from it), so a 1-shard dist run matches the sim trainer
        // bit-for-bit for every method
        let mut ctor_rng = Rng::new(seed ^ 0xABCD);
        let mut mats = Vec::new();
        for li in 0..cfg.model.n_layers {
            for (k, (rows, cols)) in layer_matrix_shapes(&cfg.model).into_iter().enumerate() {
                let mi = li * MATS_PER_LAYER + k;
                // shared seed formula (sim/trainer.rs), so per-matrix
                // projector RNG streams coincide with the sim trainer
                let ms = mat_seed(seed, li, mi);
                let mut opt = registry::build_dist_with_state(
                    method,
                    cfg.rank,
                    rows,
                    cols,
                    ms,
                    &mut ctor_rng,
                    cfg.quant.state_quant(),
                );
                mats.push(if opt.projected().is_some() {
                    MatState::Projected(ProjMat {
                        opt,
                        locals: (0..n_shards)
                            .map(|_| ShardLocal {
                                policy: ShardPolicy::for_method(method),
                                low: Matrix::zeros(0, 0),
                                vote: Decision::Keep,
                            })
                            .collect(),
                        last_switch: 0,
                    })
                } else {
                    MatState::Dense(opt)
                });
            }
        }
        let emb_opt = Adam::new(cfg.model.vocab, d);
        let norm_opts = (0..(2 * cfg.model.n_layers + 1)).map(|_| Adam::new(1, d)).collect();
        let shards = (0..n_shards)
            .map(|s| ShardState {
                sampler: ShardSampler::new(
                    cfg.model.vocab,
                    cfg.seed,
                    cfg.coherence,
                    s,
                    n_shards,
                    per_shard_batch,
                    cfg.model.seq_len,
                ),
                grads: None,
                loss: 0.0,
            })
            .collect();
        let eval_batcher = SyncBatcher::new(
            CorpusGen::new(cfg.model.vocab, cfg.seed ^ 0xEEEE, cfg.coherence),
            cfg.batch,
            cfg.model.seq_len,
        );
        Ok(DistTrainer {
            cfg: *cfg,
            method,
            world: dist.workers,
            n_shards,
            quorum: ConsensusCfg { quorum: dist.quorum },
            model,
            mats,
            emb_opt,
            norm_opts,
            shards,
            eval_batcher,
            dense_slots: vec![Matrix::zeros(0, 0); n_shards],
            pool: Pool::with_threads(dist.workers),
            topo: Topology::new(n_shards, dist.workers),
            wire_codec: cfg.quant.wire_codec(),
            comm: CommStats::default(),
            consensus: ConsensusStats::default(),
            stats: SubspaceStats::default(),
            switch_steps: Vec::new(),
            step: 0,
            eval_batches_drawn: 0,
            faults: None,
            guard: GuardCfg::default(),
            spikes: (0..n_shards).map(|_| SpikeDetector::new(GuardCfg::default())).collect(),
            forced_votes: Vec::new(),
            recovery: RecoveryStats::default(),
            rollback_stats: RollbackStats::default(),
            clip_ema: 0.0,
        })
    }

    /// Arm a seeded fault schedule: subsequent steps consult the injector
    /// for payload faults (comm layer) and step faults (kill / NaN /
    /// weight corruption).
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(FaultInjector::new(plan));
    }

    /// Configure the numerical guards (spike window / factor, rollback
    /// budget). Rebuilds every per-shard detector.
    pub fn set_guards(&mut self, guard: GuardCfg) {
        self.guard = guard;
        self.spikes = (0..self.n_shards).map(|_| SpikeDetector::new(guard)).collect();
    }

    /// Faults injected so far (zeroes when no plan is armed).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.as_ref().map(|f| f.stats).unwrap_or_default()
    }

    /// Declare `worker` dead and re-shard onto the survivors, in memory.
    ///
    /// The canonical shard decomposition is part of the arithmetic and
    /// never changes; only the worker placement does. Optimizer state is
    /// round-tripped through the same typed codec the checkpoint loader
    /// uses for cross-world restores (`opt/w{owner}/m{mi}` naming,
    /// matched back by matrix index), so the surviving run is
    /// bit-identical to a fresh N-1 run resumed from this step. The new
    /// world size is the largest `w <= world - 1` dividing the shard
    /// count (worker blocks must tile the shards).
    pub fn declare_dead(&mut self, worker: usize) -> Result<()> {
        if worker >= self.world {
            bail!("worker {worker} does not exist (world size {})", self.world);
        }
        if self.world == 1 {
            bail!("cannot remove the last worker");
        }
        let old_world = self.world;
        let mut new_world = self.world - 1;
        while self.n_shards % new_world != 0 {
            new_world -= 1;
        }
        // Export every optimizer's typed state under its current owner,
        // then restore matched by matrix index under the new placement —
        // the checkpoint re-shard math, minus the disk.
        let mut synth: Vec<(String, Matrix)> = Vec::new();
        for (mi, mat) in self.mats.iter().enumerate() {
            let owner = mi % self.world;
            mat.opt().export_state().to_tensors(&format!("opt/w{owner}/m{mi}"), &mut synth);
        }
        self.world = new_world;
        self.topo = Topology::new(self.n_shards, new_world);
        self.pool = Pool::with_threads(new_world);
        for (mi, mat) in self.mats.iter_mut().enumerate() {
            let prefix = opt_state_prefix(&synth, mi)
                .with_context(|| format!("re-shard lost optimizer state for matrix {mi}"))?;
            let state = OptState::from_tensors(&prefix, &synth).map_err(|e| anyhow!("{e}"))?;
            mat.opt_mut()
                .restore_state(state)
                .map_err(|e| anyhow!("{e}"))
                .with_context(|| format!("re-sharding optimizer state for matrix {mi}"))?;
        }
        self.recovery.worker_deaths += 1;
        crate::log_info!(
            "worker {worker} declared dead at step {}: re-sharded {old_world} -> {new_world} workers",
            self.step
        );
        Ok(())
    }

    /// The canonical model replica (read access for tests/benches).
    pub fn model(&self) -> &SimModel {
        &self.model
    }

    /// Worker count / canonical shard count of this run.
    pub fn world_size(&self) -> usize {
        self.world
    }

    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    /// Steps executed so far.
    pub fn current_step(&self) -> u64 {
        self.step
    }

    pub fn subspace_stats(&self) -> &SubspaceStats {
        &self.stats
    }

    /// Measured persistent optimizer-state bytes of one replica.
    pub fn state_bytes(&self) -> u64 {
        let mats: u64 = self.mats.iter().map(|m| m.opt().state_bytes() as u64).sum();
        mats + self.emb_opt.state_bytes() as u64
            + self.norm_opts.iter().map(|o| o.state_bytes() as u64).sum::<u64>()
    }

    /// Held-out perplexity over `n` fresh eval batches (worker-count
    /// independent: one canonical eval stream).
    pub fn eval_ppl(&mut self, n: usize) -> f64 {
        let mut total = 0.0;
        for _ in 0..n {
            let b = self.eval_batcher.next();
            total += self.model.loss(&b.tokens, &b.targets, b.batch, b.seq);
        }
        self.eval_batches_drawn += n as u64;
        (total / n as f64).exp()
    }

    /// One synchronous data-parallel step; returns the mean training
    /// loss over the total batch, or [`StepOutcome::NonFinite`] when the
    /// numerical guard withheld the update. Errors are unrecoverable
    /// comm failures (retry budget exhausted).
    pub fn step_once(&mut self) -> Result<StepOutcome> {
        let _step_sp = span(SpanKind::Step);
        self.step += 1;
        let t = self.step;
        let hyper = self.cfg.hyper;
        let n_layers = self.cfg.model.n_layers;
        let inv_s = 1.0 / self.n_shards as f32;

        // ---- scheduled step faults fire before the step executes ----
        let mut poison_grads = false;
        let step_faults = match self.faults.as_mut() {
            Some(inj) => {
                inj.begin_step(t);
                inj.step_faults()
            }
            None => Vec::new(),
        };
        for ev in step_faults {
            match ev {
                FaultKind::KillWorker(w) => self.declare_dead(w)?,
                FaultKind::NanGrad => poison_grads = true,
                FaultKind::CorruptWeights => {
                    // silent parameter corruption: scaling the tied
                    // embedding scales the logits directly (the input-path
                    // scale is absorbed by RMSNorm), so the loss spikes,
                    // the windowed detector catches it, rollback repairs it
                    self.model.params.embed.scale(25.0);
                    crate::log_info!("injected weight corruption at step {t}");
                }
                FaultKind::FalseVote(s) => {
                    // no arithmetic perturbation — the shard only *votes*
                    // to roll back at the end of this step, exercising
                    // quorum rejection of a lone false positive
                    if s < self.n_shards {
                        self.forced_votes.push(s);
                        crate::log_info!("injected false rollback vote from shard {s} at step {t}");
                    } else {
                        crate::log_info!("false-vote fault targets shard {s} (only {} shards) — ignored", self.n_shards);
                    }
                }
                other => unreachable!("payload fault {other:?} scheduled as a step fault"),
            }
        }

        // ---- local gradients: shards fan out across the worker pool ----
        {
            let _sp = span(SpanKind::Grad);
            let model = &self.model;
            let topo = &self.topo;
            self.pool.par_items_mut(&mut self.shards, |s, sh| {
                let _lane = span::lane_scope(topo.owner(s));
                let b = sh.sampler.next();
                let (loss, grads) = model.loss_and_grad(&b.tokens, &b.targets, b.batch, b.seq);
                sh.loss = loss;
                sh.grads = Some(grads);
            });
        }
        if poison_grads {
            let g = self.shards[0].grads.as_mut().unwrap();
            grad_mat_mut(g, 0).data[0] = f32::NAN;
            crate::log_info!("injected NaN gradient at step {t}");
        }
        // mean loss folded in canonical shard order (worker-invariant)
        let loss = self.shards.iter().map(|s| s.loss).sum::<f64>() / self.n_shards as f64;

        // ---- numerical guard: a non-finite loss or gradient withholds
        // every update this step (nothing may leak into the moments) ----
        if !loss.is_finite()
            || self.shards.iter().any(|sh| sh.grads.as_ref().unwrap().has_non_finite())
        {
            return Ok(StepOutcome::NonFinite);
        }

        // ---- per-shard global-norm clipping (off at 0.0): canonical
        // shard gradients are clipped independently, so the result is
        // worker-invariant and a 1-shard run matches the sim trainer
        // bit for bit. Runs upstream of the loss-spike detector ----
        if self.guard.clip_norm > 0.0 {
            let mut max_pre = 0.0f64;
            let mut clipped = 0u64;
            for sh in self.shards.iter_mut() {
                let g = sh.grads.as_mut().unwrap();
                let pre = grad_full_norm(g);
                max_pre = max_pre.max(pre);
                if pre > self.guard.clip_norm {
                    clipped += 1;
                    scale_gradients(g, (self.guard.clip_norm / pre) as f32);
                }
            }
            let anomaly = if self.clip_ema > 0.0 { max_pre / self.clip_ema } else { 1.0 };
            self.clip_ema =
                if self.clip_ema > 0.0 { 0.9 * self.clip_ema + 0.1 * max_pre } else { max_pre };
            if clipped > 0 {
                self.recovery.clipped_steps += 1;
                if telemetry::metrics_enabled() {
                    telemetry::emit_record(&JsonValue::obj(vec![
                        ("type", JsonValue::str("clipped")),
                        ("step", JsonValue::num(t as f64)),
                        ("grad_norm", JsonValue::num(max_pre)),
                        ("clip_norm", JsonValue::num(self.guard.clip_norm)),
                        ("anomaly", JsonValue::num(anomaly)),
                        ("shards", JsonValue::num(clipped as f64)),
                    ]));
                }
            }
        }

        let Self {
            mats,
            shards,
            model,
            dense_slots,
            comm,
            consensus,
            stats,
            pool,
            topo,
            quorum,
            switch_steps,
            norm_opts,
            emb_opt,
            faults,
            wire_codec,
            ..
        } = self;
        let n_shards = shards.len();
        let codec = *wire_codec;

        // ---- per-matrix update ----
        for (mi, mat) in mats.iter_mut().enumerate() {
            match mat {
                MatState::Dense(opt) => {
                    // dense all-reduce in place over the shard gradients;
                    // the canonical optimizer (Adam, adapters, Apollo, …)
                    // then steps once on the averaged gradient
                    let edges = tree_reduce_quantized(
                        shards,
                        |sh| &mut grad_mat_mut(sh.grads.as_mut().unwrap(), mi).data[..],
                        topo,
                        codec,
                        faults.as_mut(),
                        comm,
                    )?;
                    let g = grad_mat_mut(shards[0].grads.as_mut().unwrap(), mi);
                    g.scale(inv_s);
                    comm.record_other_dense(edges, codec.encoded_len(g.len()) as u64);
                    let ev = opt.step(weight_mat(&mut model.params, mi), g, &hyper, t);
                    stats.record_observation();
                    match ev {
                        StepEvent::Switched { reason, lifetime, .. } => {
                            stats.record_switch(reason, lifetime);
                            if mi == 0 {
                                switch_steps.push(t);
                            }
                        }
                        StepEvent::Merged { .. } => stats.record_merge(),
                        StepEvent::None | StepEvent::SkippedNonFinite => {}
                    }
                }
                MatState::Projected(pm) => {
                    let ProjMat { opt, locals, last_switch } = pm;
                    let cap = opt.projected().expect("ProjMat requires the capability");
                    let fitted = cap.projection().is_some();

                    // A: project + vote with the *local* shard gradient
                    if let Some(p) = cap.projection() {
                        let shard_view: &[ShardState] = &shards[..];
                        let topo_view: &Topology = topo;
                        pool.par_items_mut(locals, |s, loc| {
                            let _lane = span::lane_scope(topo_view.owner(s));
                            let g = grad_mat(shard_view[s].grads.as_ref().unwrap(), mi);
                            p.down_into(g, &mut loc.low);
                            loc.vote =
                                loc.policy.observe(&Observation { low_grad: &loc.low, step: t });
                        });
                    }

                    // B: shard-indexed consensus (worker-count invariant)
                    let reason = if !fitted {
                        Some(SwitchReason::Init)
                    } else {
                        let votes: Vec<Decision> = locals.iter().map(|l| l.vote).collect();
                        let d = decide(&votes, quorum);
                        consensus.record_round(&votes, d.is_some());
                        comm.record_votes(topo.cross_edges(), n_shards as u64);
                        d
                    };

                    // C: lockstep refresh from the all-reduced dense
                    // gradient — the only dense exchange
                    if let Some(r) = reason {
                        for (s, slot) in dense_slots.iter_mut().enumerate() {
                            slot.copy_from(grad_mat(shards[s].grads.as_ref().unwrap(), mi));
                        }
                        let edges = tree_reduce_quantized(
                            dense_slots,
                            |m| &mut m.data[..],
                            topo,
                            codec,
                            faults.as_mut(),
                            comm,
                        )?;
                        let g_avg = &mut dense_slots[0];
                        g_avg.scale(inv_s);
                        comm.record_refresh_dense(edges, codec.encoded_len(g_avg.len()) as u64);
                        cap.refit_from(g_avg, t);
                        // re-project + reset policy replicas in the new
                        // subspace (lockstep across shards)
                        let p = cap.projection().expect("refit fitted a projection");
                        let shard_view: &[ShardState] = &shards[..];
                        let topo_view: &Topology = topo;
                        pool.par_items_mut(locals, |s, loc| {
                            let _lane = span::lane_scope(topo_view.owner(s));
                            let g = grad_mat(shard_view[s].grads.as_ref().unwrap(), mi);
                            p.down_into(g, &mut loc.low);
                            loc.policy.reset(&loc.low, t);
                        });
                        stats.record_switch(r, t.saturating_sub(*last_switch));
                        *last_switch = t;
                        if mi == 0 {
                            switch_steps.push(t);
                        }
                    }

                    // D: all-reduce of the r×n projected gradient — the
                    // steady-state traffic the subspace makes cheap
                    let dense_payload =
                        (grad_mat(shards[0].grads.as_ref().unwrap(), mi).len() * 4) as u64;
                    let edges = tree_reduce_quantized(
                        locals,
                        |loc| &mut loc.low.data[..],
                        topo,
                        codec,
                        faults.as_mut(),
                        comm,
                    )?;
                    locals[0].low.scale(inv_s);
                    comm.record_lowrank(
                        edges,
                        codec.encoded_len(locals[0].low.len()) as u64,
                        dense_payload,
                    );

                    // E: canonical replica update (identical everywhere)
                    cap.step_preprojected(
                        weight_mat(&mut model.params, mi),
                        &locals[0].low,
                        &hyper,
                        t,
                    );
                    stats.record_observation();
                }
            }
        }

        // ---- tensors that are dense in every method: reduce, then run
        // the update block shared with SimTrainer (1/S folded in) ----
        for li in 0..n_layers {
            let e1 = tree_reduce_quantized(
                shards,
                |sh| &mut sh.grads.as_mut().unwrap().layers[li].norm1[..],
                topo,
                codec,
                faults.as_mut(),
                comm,
            )?;
            let e2 = tree_reduce_quantized(
                shards,
                |sh| &mut sh.grads.as_mut().unwrap().layers[li].norm2[..],
                topo,
                codec,
                faults.as_mut(),
                comm,
            )?;
            let d_bytes = codec.encoded_len(model.params.layers[li].norm1.len()) as u64;
            comm.record_other_dense(e1, d_bytes);
            comm.record_other_dense(e2, d_bytes);
        }
        let ef = tree_reduce_quantized(
            shards,
            |sh| &mut sh.grads.as_mut().unwrap().final_norm[..],
            topo,
            codec,
            faults.as_mut(),
            comm,
        )?;
        comm.record_other_dense(ef, codec.encoded_len(model.params.final_norm.len()) as u64);
        let ee = tree_reduce_quantized(
            shards,
            |sh| &mut sh.grads.as_mut().unwrap().embed.data[..],
            topo,
            codec,
            faults.as_mut(),
            comm,
        )?;
        comm.record_other_dense(ee, codec.encoded_len(model.params.embed.len()) as u64);
        dense_tail_update(
            &mut model.params,
            shards[0].grads.as_mut().unwrap(),
            norm_opts,
            emb_opt,
            &hyper,
            t,
            inv_s,
        );

        Ok(StepOutcome::Stepped(loss))
    }

    /// Run `steps` training steps and report.
    pub fn train(&mut self, steps: u64) -> DistReport {
        self.train_checkpointed(steps, 0, "", "run")
            .expect("train without checkpointing or armed faults cannot fail")
    }

    /// Like [`Self::train`], saving a checkpoint every `every` steps
    /// into `out_dir` (the CLI's `ckpt_every` semantics, matching the
    /// PJRT trainer); `every == 0` disables saving.
    pub fn train_checkpointed(
        &mut self,
        steps: u64,
        every: u64,
        out_dir: &str,
        name: &str,
    ) -> Result<DistReport> {
        let t_total = std::time::Instant::now();
        let mut report = DistReport {
            method: self.method.name(),
            steps,
            workers: self.world,
            shards: self.n_shards,
            final_ppl: f64::NAN,
            losses: Vec::new(),
            loss_curve: Vec::new(),
            eval_curve: Vec::new(),
            stats: SubspaceStats::default(),
            comm: CommStats::default(),
            consensus: ConsensusStats::default(),
            switch_steps: Vec::new(),
            state_bytes: 0,
            total_s: 0.0,
            recovery: RecoveryStats::default(),
            rollback: RollbackStats::default(),
            faults: FaultStats::default(),
        };
        let start = self.step;
        let target = start + steps;
        // steps whose losses are in report.losses — lets a rollback
        // truncate the curves to exactly the restored step
        let mut loss_steps: Vec<u64> = Vec::new();
        // retained periodic checkpoints in ascending step order — the
        // quorum protocol restores the newest entry ≤ the agreed bound
        let mut ckpt_history: Vec<(u64, String)> = Vec::new();
        while self.step < target {
            let emit = telemetry::metrics_enabled();
            let (ns0, c0) = if emit {
                (telemetry::phase_totals_ns(), telemetry::phase_counts())
            } else {
                ([0u64; SPAN_KINDS], [0u64; SPAN_KINDS])
            };
            let bytes0 = if emit { self.comm.total_bytes() } else { 0 };
            match self.step_once()? {
                StepOutcome::NonFinite => {
                    self.forced_votes.clear();
                    // the reduced gradient is bit-identical on every
                    // replica, so the non-finite guard fires unanimously
                    let votes: Vec<RollbackVote> =
                        vec![Some(self.step.saturating_sub(1)); self.n_shards];
                    let rolled = self.recovery_round(
                        &votes,
                        "non_finite",
                        &ckpt_history,
                        &mut report,
                        &mut loss_steps,
                    )?;
                    if !rolled {
                        self.recovery.skipped_steps += 1;
                        crate::log_info!(
                            "step {}: non-finite loss/gradient — update skipped",
                            self.step
                        );
                    }
                    continue;
                }
                StepOutcome::Stepped(loss) => {
                    let t = self.step;
                    // ---- per-shard guards vote on their local losses;
                    // forced false-positive votes ride the same round ----
                    let mut votes: Vec<RollbackVote> = vec![None; self.n_shards];
                    let mut detector_fired = false;
                    for s in 0..self.n_shards {
                        let local = self.shards[s].loss;
                        if self.spikes[s].observe(local) {
                            votes[s] = Some(t.saturating_sub(1));
                            detector_fired = true;
                        }
                    }
                    for s in std::mem::take(&mut self.forced_votes) {
                        votes[s] = Some(t.saturating_sub(1));
                    }
                    if votes.iter().any(|v| v.is_some()) {
                        if detector_fired {
                            self.recovery.loss_spikes += 1;
                        }
                        let cause = if detector_fired { "spike" } else { "false_vote" };
                        let rolled = self.recovery_round(
                            &votes,
                            cause,
                            &ckpt_history,
                            &mut report,
                            &mut loss_steps,
                        )?;
                        if rolled {
                            continue;
                        }
                    }
                    report.losses.push(loss);
                    loss_steps.push(t);
                    if emit {
                        let ns1 = telemetry::phase_totals_ns();
                        let c1 = telemetry::phase_counts();
                        telemetry::emit_record(&JsonValue::obj(vec![
                            ("type", JsonValue::str("dist_step")),
                            ("step", JsonValue::num(t as f64)),
                            ("loss", JsonValue::num(loss)),
                            (
                                "comm_bytes",
                                JsonValue::num((self.comm.total_bytes() - bytes0) as f64),
                            ),
                            (
                                "switches_total",
                                JsonValue::num(self.stats.subspace_count as f64),
                            ),
                            ("wall", telemetry::phase_delta_json(&ns0, &c0, &ns1, &c1)),
                        ]));
                    }
                    if diag::prom_enabled() {
                        telemetry::REGISTRY.gauge("train.step").set(t);
                        telemetry::REGISTRY.gauge("train.loss_micro").set(diag::micro(loss));
                        diag::flush_prom();
                    }
                    if t % 10 == 0 || t == 1 {
                        report.loss_curve.push((t, loss));
                    }
                    if t % self.cfg.eval_every == 0 {
                        let _sp = span(SpanKind::Eval);
                        let ppl = self.eval_ppl(self.cfg.eval_batches);
                        report.eval_curve.push((t, ppl));
                    }
                    if every > 0 && (t - start) % every == 0 {
                        std::fs::create_dir_all(out_dir)?;
                        let path = format!("{out_dir}/{name}-step{t}.ckpt");
                        self.save_checkpoint(&path)?;
                        crate::log_info!("checkpoint saved: {path}");
                        // a replayed save after a rollback overwrote the
                        // file in place — drop any stale entries at or
                        // past this step before retaining the new one
                        ckpt_history.retain(|(s, _)| *s < t);
                        ckpt_history.push((t, path));
                    }
                }
            }
        }
        report.final_ppl = self.eval_ppl(self.cfg.eval_batches * 2);
        report.stats = self.stats.clone();
        report.comm = self.comm.clone();
        report.consensus = self.consensus.clone();
        report.switch_steps = self.switch_steps.clone();
        report.state_bytes = self.state_bytes();
        report.total_s = t_total.elapsed().as_secs_f64();
        report.recovery = self.recovery;
        report.rollback = self.rollback_stats;
        report.faults = self.fault_stats();
        Ok(report)
    }

    /// Hold one quorum recovery round over shard-indexed rollback votes.
    ///
    /// The vote payload — one f32 word per shard proposal plus one slot
    /// carrying the folded minimum bound — crosses every wire edge of
    /// the reduction tree through the checksummed, retried transfer
    /// path ([`exchange_votes`]), so a corrupted or dropped vote is
    /// detected and resent like any gradient payload. The decision is
    /// folded with [`decide_rollback`] (same quorum rule as the
    /// displacement votes) and surfaced as a typed `rollback_vote`
    /// JSONL record. On quorum, every replica restores the newest
    /// retained checkpoint ≤ the minimum proposed step, in lockstep.
    /// Returns whether a rollback was executed.
    fn recovery_round(
        &mut self,
        votes: &[RollbackVote],
        cause: &'static str,
        history: &[(u64, String)],
        report: &mut DistReport,
        loss_steps: &mut Vec<u64>,
    ) -> Result<bool> {
        let t = self.step;
        let d = decide_rollback(votes, &self.quorum);
        // shard-indexed wire image: proposal step + 1 per shard (0 =
        // continue), one slot for the folded bound — small enough that
        // f32 words are exact (steps < 2^24)
        let mut payload: Vec<f32> =
            votes.iter().map(|v| v.map_or(0.0, |s| (s + 1) as f32)).collect();
        payload.push(d.min_step.map_or(0.0, |s| (s + 1) as f32));
        exchange_votes(&payload, &self.topo, self.faults.as_mut(), &mut self.comm)
            .map_err(|e| anyhow!("rollback vote exchange failed: {e}"))?;
        let agreed = if d.rollback {
            d.min_step.and_then(|bound| agreed_checkpoint(history, bound))
        } else {
            None
        };
        let restore =
            agreed.filter(|_| self.recovery.rollbacks < self.guard.max_rollbacks as u64).cloned();
        self.rollback_stats.record_round(&d, restore.is_some());
        if telemetry::metrics_enabled() {
            let vote_list = JsonValue::arr(
                votes
                    .iter()
                    .map(|v| match v {
                        Some(s) => JsonValue::num(*s as f64),
                        None => JsonValue::num(-1.0),
                    })
                    .collect(),
            );
            telemetry::emit_record(&JsonValue::obj(vec![
                ("type", JsonValue::str("rollback_vote")),
                ("step", JsonValue::num(t as f64)),
                ("cause", JsonValue::str(cause)),
                ("votes", vote_list),
                ("proposals", JsonValue::num(d.proposals as f64)),
                ("voters", JsonValue::num(d.voters as f64)),
                ("needed", JsonValue::num(d.needed as f64)),
                ("quorum", JsonValue::num(if d.rollback { 1.0 } else { 0.0 })),
                (
                    "agreed_step",
                    JsonValue::num(restore.as_ref().map_or(-1.0, |(s, _)| *s as f64)),
                ),
            ]));
        }
        if !d.rollback {
            crate::log_info!(
                "step {t}: rollback proposal outvoted ({}/{} votes, {} needed) — continuing",
                d.proposals,
                d.voters,
                d.needed
            );
            return Ok(false);
        }
        match restore {
            Some((astep, apath)) => {
                crate::log_info!(
                    "step {t}: quorum rollback ({}/{} votes, cause {cause}) to step {astep}",
                    d.proposals,
                    d.voters
                );
                self.rollback_to(&apath, report, loss_steps)?;
                Ok(true)
            }
            None => {
                crate::log_info!(
                    "step {t}: quorum reached ({}/{} votes, cause {cause}) but no retained \
                     checkpoint / rollback budget — continuing degraded",
                    d.proposals,
                    d.voters
                );
                Ok(false)
            }
        }
    }

    /// Roll back to the last good periodic checkpoint: weights, typed
    /// optimizer state, policy replicas and data cursors are restored and
    /// the RNG-backed streams replayed, so the recovered trajectory is
    /// byte-exact to a run that never took the bad step. Curves are
    /// truncated back to the restored step.
    fn rollback_to(
        &mut self,
        path: &str,
        report: &mut DistReport,
        loss_steps: &mut Vec<u64>,
    ) -> Result<u64> {
        let _sp = span(SpanKind::Rollback);
        let bad = self.step;
        let restored = self.load_checkpoint(path)?;
        for d in &mut self.spikes {
            d.reset();
        }
        self.recovery.rollbacks += 1;
        let keep = loss_steps.iter().take_while(|&&s| s <= restored).count();
        loss_steps.truncate(keep);
        report.losses.truncate(keep);
        report.loss_curve.retain(|&(s, _)| s <= restored);
        report.eval_curve.retain(|&(s, _)| s <= restored);
        // the deterministic replay regenerates these; cumulative
        // diagnostics (SubspaceStats, CommStats) keep the discarded work
        self.switch_steps.retain(|&s| s <= restored);
        crate::log_info!("step {bad}: rolled back to checkpoint at step {restored} ({path})");
        Ok(restored)
    }

    /// Save the full training state: replica params, every canonical
    /// optimizer's typed [`OptState`] (named per save-time owner,
    /// ZeRO-style), every shard's policy replica, and the data cursors.
    /// Loading under a different worker count re-shards the state
    /// ([`Self::load_checkpoint`]).
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let _sp = span(SpanKind::Checkpoint);
        // Weights — the tensors that dominate peak memory — are
        // *borrowed*; optimizer state flows through the typed OptState
        // codec (a transient copy, low-rank sized for the projected
        // methods; for the dense full-rank baseline this means one
        // moments-sized allocation during the save) and the per-shard
        // policy replicas through the PolicyState codec.
        let (mut synth, refs) = self.model.params.export_tensors();
        for (mi, mat) in self.mats.iter().enumerate() {
            let owner = mi % self.world;
            let prefix = format!("opt/w{owner}/m{mi}");
            mat.opt().export_state().to_tensors(&prefix, &mut synth);
            if let MatState::Projected(pm) = mat {
                // engine-level meta: the last consensus switch step
                let mut meta = Vec::with_capacity(4);
                push_u64(&mut meta, pm.last_switch);
                let cols = meta.len();
                synth.push((format!("{prefix}/engine"), Matrix::from_vec(1, cols, meta)));
                for (s, loc) in pm.locals.iter().enumerate() {
                    loc.policy
                        .export_state()
                        .to_tensors(&format!("policy/s{s}/m{mi}"), &mut synth);
                }
            }
        }
        self.emb_opt.export_state().to_tensors("opt/emb", &mut synth);
        for (i, o) in self.norm_opts.iter().enumerate() {
            o.export_state().to_tensors(&format!("opt/norm{i}"), &mut synth);
        }
        // [world, shards, eval_batches_drawn(4)]
        let mut meta = vec![self.world as f32, self.n_shards as f32];
        push_u64(&mut meta, self.eval_batches_drawn);
        let cols = meta.len();
        synth.push((DIST_META.into(), Matrix::from_vec(1, cols, meta)));

        let mut tensors: Vec<(String, &Matrix)> = refs;
        tensors.extend(synth.iter().map(|(n, m)| (n.clone(), m)));
        checkpoint::save_refs(path, self.step, &tensors)
    }

    /// Restore a [`Self::save_checkpoint`] file. The current worker count
    /// may differ from the save-time one — optimizer state is re-sharded
    /// by matrix index — but the canonical shard decomposition must
    /// match (it is part of the arithmetic). Data streams are replayed to
    /// the saved cursor, so subsequent steps are bit-identical to an
    /// uninterrupted run.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<u64> {
        let (step, tensors) = checkpoint::load(path)?;
        let meta = find(&tensors, DIST_META)?;
        let saved_shards = meta.data[1] as usize;
        if saved_shards != self.n_shards {
            bail!(
                "checkpoint was taken with {saved_shards} shards but this run uses {} — \
                 the shard decomposition is part of the experiment (the worker count is not)",
                self.n_shards
            );
        }
        let eval_drawn = read_u64_limbs(&meta.data, 2);
        self.model.params.restore_from_tensors(&tensors).map_err(|e| anyhow!("{e}"))?;
        for (mi, mat) in self.mats.iter_mut().enumerate() {
            let prefix = opt_state_prefix(&tensors, mi)
                .with_context(|| format!("checkpoint missing optimizer state for matrix {mi}"))?;
            let state =
                OptState::from_tensors(&prefix, &tensors).map_err(|e| anyhow!("{e}"))?;
            mat.opt_mut()
                .restore_state(state)
                .map_err(|e| anyhow!("{e}"))
                .with_context(|| format!("restoring optimizer state for matrix {mi}"))?;
            if let MatState::Projected(pm) = mat {
                let engine_meta = find(&tensors, &format!("{prefix}/engine"))?;
                pm.last_switch = read_u64_limbs(&engine_meta.data, 0);
                for (s, loc) in pm.locals.iter_mut().enumerate() {
                    let ps = PolicyState::from_tensors(&format!("policy/s{s}/m{mi}"), &tensors)
                        .map_err(|e| anyhow!("{e}"))?;
                    loc.policy.restore_state(ps).map_err(|e| anyhow!("{e}"))?;
                }
            }
        }
        let emb = OptState::from_tensors("opt/emb", &tensors).map_err(|e| anyhow!("{e}"))?;
        self.emb_opt.restore_state(emb).map_err(|e| anyhow!("{e}"))?;
        for (i, o) in self.norm_opts.iter_mut().enumerate() {
            let s = OptState::from_tensors(&format!("opt/norm{i}"), &tensors)
                .map_err(|e| anyhow!("{e}"))?;
            o.restore_state(s).map_err(|e| anyhow!("{e}"))?;
        }
        // rebuild the deterministic data streams from scratch and replay
        // them to the saved cursor — correct even when this trainer has
        // already stepped (loading is a rollback, not a continuation)
        let per_shard_batch = self.cfg.batch / self.n_shards;
        for (s, sh) in self.shards.iter_mut().enumerate() {
            sh.sampler = ShardSampler::new(
                self.cfg.model.vocab,
                self.cfg.seed,
                self.cfg.coherence,
                s,
                self.n_shards,
                per_shard_batch,
                self.cfg.model.seq_len,
            );
            sh.sampler.skip(step);
            sh.grads = None;
            sh.loss = 0.0;
        }
        self.eval_batcher = SyncBatcher::new(
            CorpusGen::new(self.cfg.model.vocab, self.cfg.seed ^ 0xEEEE, self.cfg.coherence),
            self.cfg.batch,
            self.cfg.model.seq_len,
        );
        for _ in 0..eval_drawn {
            let _ = self.eval_batcher.next();
        }
        self.eval_batches_drawn = eval_drawn;
        self.step = step;
        Ok(step)
    }
}

fn find<'a>(tensors: &'a [(String, Matrix)], name: &str) -> Result<&'a Matrix> {
    tensors
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, m)| m)
        .with_context(|| format!("checkpoint missing tensor '{name}'"))
}

/// Optimizer states are saved under their save-time owner
/// (`opt/w{w}/m{mi}/...`); the loader matches by matrix index alone so a
/// different world size re-shards the state transparently. Returns the
/// full save-time prefix (e.g. `opt/w3/m17`) of the state's `kind`
/// tensor.
fn opt_state_prefix(tensors: &[(String, Matrix)], mi: usize) -> Option<String> {
    let suffix = format!("/m{mi}/kind");
    tensors
        .iter()
        .find(|(n, _)| n.starts_with("opt/w") && n.ends_with(&suffix))
        .map(|(n, _)| n[..n.len() - "/kind".len()].to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_cfg_validation() {
        assert!(DistCfg::with_workers(1).validate(8).is_ok());
        assert!(DistCfg { workers: 2, shards: 4, quorum: 0.5 }.validate(8).is_ok());
        // workers must divide shards
        assert!(DistCfg { workers: 3, shards: 4, quorum: 0.5 }.validate(8).is_err());
        // shards must divide batch
        assert!(DistCfg { workers: 1, shards: 3, quorum: 0.5 }.validate(8).is_err());
        // quorum range
        assert!(DistCfg { workers: 1, shards: 1, quorum: 0.0 }.validate(8).is_err());
        assert!(DistCfg { workers: 0, shards: 0, quorum: 0.5 }.validate(8).is_err());
        // shards default to workers
        assert_eq!(DistCfg::with_workers(4).shard_count(), 4);
        assert!(DistCfg::with_workers(4).is_distributed());
        assert!(!DistCfg::default().is_distributed());
    }

    #[test]
    fn adapter_methods_run_densely_in_dist() {
        // LoRA exposes no projected-gradient capability, so the engine
        // drives it with the densely all-reduced gradient — before the
        // unified Optimizer trait it was rejected outright.
        let mut cfg = SimRunCfg::quick(crate::models::presets::llama_tiny_cfg(), 8, 3);
        cfg.batch = 4;
        cfg.eval_every = 1_000_000;
        cfg.eval_batches = 1;
        let mut t = DistTrainer::new(&cfg, Method::LoRA, DistCfg::with_workers(2), 1).unwrap();
        let r = t.train(3);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert_eq!(r.comm.lowrank_bytes, 0, "adapters reduce densely");
        assert!(r.comm.other_dense_bytes > 0);
    }

    #[test]
    fn declare_dead_reshards_to_largest_divisor_world() {
        let mut cfg = SimRunCfg::quick(crate::models::presets::llama_tiny_cfg(), 8, 4);
        cfg.batch = 4;
        cfg.eval_every = 1_000_000;
        cfg.eval_batches = 1;
        let dist = DistCfg { workers: 4, shards: 4, quorum: 0.5 };
        let mut t = DistTrainer::new(&cfg, Method::lotus_default(), dist, 1).unwrap();
        let _ = t.train(2);
        t.declare_dead(3).unwrap();
        // 3 survivors cannot tile 4 shards; the engine drops to 2.
        assert_eq!(t.world_size(), 2);
        assert_eq!(t.shard_count(), 4, "the shard decomposition never changes");
        let r = t.train(2);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert_eq!(r.recovery.worker_deaths, 1);
        // the last worker cannot be removed
        t.declare_dead(0).unwrap();
        assert_eq!(t.world_size(), 1);
        assert!(t.declare_dead(0).is_err());
    }

    #[test]
    fn batch_must_tile_into_shards() {
        let mut cfg = SimRunCfg::quick(crate::models::presets::llama_tiny_cfg(), 8, 4);
        cfg.batch = 6;
        let err = DistTrainer::new(&cfg, Method::lotus_default(), DistCfg::with_workers(4), 1);
        assert!(err.is_err());
    }
}
