//! Counting-allocator proof of the allocation-free hot path: at steady
//! state (projector fitted, scratch warm, no subspace switch pending),
//! the projected update — down-project → policy observation → Adam
//! moment update → fused lift-into-weight — performs **zero** heap
//! allocations per step. This is the projection/update path one
//! sim-trainer step runs per projected matrix; the shapes below are the
//! `llama_tiny` layer shapes the simulator trains.
//!
//! Kept in its own integration-test binary so the global allocator hook
//! and the single-test process give a quiet measurement window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use lotus::optim::lowrank::presets;
use lotus::optim::{Hyper, LowRankAdam, Optimizer};
use lotus::tensor::Matrix;
use lotus::util::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Warm an optimizer, then count heap allocations across `steps` steady
/// steps on a fixed gradient stream. Returns the allocation count.
fn count_steady_allocs(opt: &mut LowRankAdam, m: usize, n: usize, steps: u64) -> u64 {
    let mut rng = Rng::new(301);
    let mut w = Matrix::randn(m, n, 0.1, &mut rng);
    let g0 = Matrix::randn(m, n, 1.0, &mut rng);
    let g1 = Matrix::randn(m, n, 1.0, &mut rng);
    let hyper = Hyper { lr: 1e-3, galore_scale: 0.25, weight_decay: 0.0, ..Default::default() };

    // Warm-up: fit the subspace, size every scratch buffer, and cross at
    // least one η verification boundary for the adaptive policy.
    for t in 1..=12 {
        let g = if t % 2 == 0 { &g0 } else { &g1 };
        opt.step(&mut w, g, &hyper, t);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 13..(13 + steps) {
        let g = if t % 2 == 0 { &g0 } else { &g1 };
        opt.step(&mut w, g, &hyper, t);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert!(w.fro_norm().is_finite());
    after - before
}

#[test]
fn steady_state_projected_update_is_allocation_free() {
    // llama_tiny projected-layer shapes: attention d×d and SwiGLU d×f/f×d.
    for (m, n) in [(128usize, 128usize), (128, 344), (344, 128)] {
        // GaLore-style: fixed interval far beyond the horizon → pure
        // steady state after the init fit.
        let mut galore = presets::galore(16, 1_000_000);
        let a = count_steady_allocs(&mut galore, m, n, 100);
        assert_eq!(a, 0, "galore path allocated {a} times at steady state ({m}x{n})");

        // Lotus: the adaptive policy observes every step (normalization +
        // displacement reduction) but a vanishing γ never triggers a
        // switch — the full Algorithm 1 observation path must be free too.
        let mut lotus = presets::lotus(16, 1e-300, 5, 5, 7);
        let a = count_steady_allocs(&mut lotus, m, n, 100);
        assert_eq!(a, 0, "lotus path allocated {a} times at steady state ({m}x{n})");
    }
}
