#![cfg(feature = "pjrt")]

//! End-to-end PJRT training: short Lotus and GaLore runs on the tiny
//! config — loss must decrease, switching must engage, checkpoints must
//! round-trip. Self-skips without artifacts.

use lotus::config::RunConfig;
use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::Method;
use lotus::train::PjrtTrainer;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn tiny_run(steps: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = llama_tiny_cfg();
    cfg.method.rank = 16;
    cfg.batch = 4; // must match aot.py BATCHES["tiny"]
    cfg.steps = steps;
    cfg.name = format!("e2e-test-{steps}");
    cfg.out_dir = std::env::temp_dir().join("lotus_e2e").to_string_lossy().into_owned();
    cfg.hyper.lr = 3e-3;
    cfg.hyper.galore_scale = 1.0;
    cfg
}

#[test]
fn lotus_pjrt_training_reduces_loss_and_switches() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = tiny_run(30);
    let method = Method::Lotus { gamma: 0.05, eta: 5, t_min: 5 };
    let mut t = PjrtTrainer::new(cfg, method).unwrap();
    let report = t.train(30).unwrap();
    // learning: loss down from ~ln(512)≈6.2
    let first = report.loss_curve.first().unwrap().1;
    assert!(report.final_loss < first, "loss {first} -> {}", report.final_loss);
    assert!(report.final_loss.is_finite());
    // all 14 projected matrices initialized a subspace
    assert!(report.stats.subspace_count >= 14, "subspaces {}", report.stats.subspace_count);
}

#[test]
fn galore_pjrt_switches_on_interval() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = tiny_run(21);
    let method = Method::GaLore { interval: 10 };
    let mut t = PjrtTrainer::new(cfg, method).unwrap();
    let report = t.train(21).unwrap();
    // 14 inits + 2 interval rounds × 14 = 42
    assert_eq!(report.stats.subspace_count, 42, "{}", report.stats.subspace_count);
    assert!(report.final_loss.is_finite());
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = tiny_run(4);
    let method = Method::Lotus { gamma: 0.01, eta: 50, t_min: 50 };
    let mut t = PjrtTrainer::new(cfg.clone(), method).unwrap();
    t.train(4).unwrap();
    let path = std::env::temp_dir().join("lotus_e2e_ckpt.ckpt");
    let path_s = path.to_string_lossy().into_owned();
    t.save_checkpoint(&path_s).unwrap();
    let w_before = t.params().entries[1].1.clone();

    let mut t2 = PjrtTrainer::new(cfg, method).unwrap();
    let step = t2.load_checkpoint(&path_s).unwrap();
    assert_eq!(step, 4);
    assert_eq!(t2.params().entries[1].1, w_before, "bit-exact restore");
    let _ = std::fs::remove_file(path);
}

#[test]
fn mismatched_batch_is_rejected() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut cfg = tiny_run(2);
    cfg.batch = 3; // artifact baked with batch 4
    let err = PjrtTrainer::new(cfg, Method::GaLore { interval: 5 });
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("batch"), "{msg}");
}

#[test]
fn sim_and_pjrt_loss_curves_track_each_other() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    // Same method/seed on both paths: curves won't be identical (rsvd Ω
    // streams differ) but first-step losses must match and both must
    // drop by a similar factor.
    use lotus::sim::trainer::{SimRunCfg, SimTrainer};
    let steps = 15u64;
    let cfg = tiny_run(steps);
    let mut pjrt =
        PjrtTrainer::new(cfg.clone(), Method::Lotus { gamma: 0.01, eta: 50, t_min: 50 })
            .unwrap();
    let pj = pjrt.train(steps).unwrap();

    let sim_cfg = SimRunCfg {
        model: cfg.model,
        rank: cfg.method.rank,
        batch: cfg.batch,
        steps,
        eval_every: steps,
        eval_batches: 2,
        hyper: cfg.hyper,
        seed: cfg.seed,
        coherence: cfg.coherence,
        quant: cfg.quant,
        clip_norm: 0.0,
    };
    let mut sim = SimTrainer::new(&sim_cfg, Method::Lotus { gamma: 0.01, eta: 50, t_min: 50 }, cfg.seed);
    let sr = sim.train(steps);

    let pj_first = pj.loss_curve.first().unwrap().1;
    let sim_first = sr.loss_curve.first().unwrap().1;
    // same init + same data stream ⇒ same first loss
    assert!(
        (pj_first - sim_first).abs() / sim_first < 5e-3,
        "first-step losses diverge: {pj_first} vs {sim_first}"
    );
}
