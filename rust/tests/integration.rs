//! Cross-module integration tests that need no artifacts: full sim
//! training across all methods, the GLUE-sim suite, data pipeline →
//! trainer composition, memory-model vs measured-state agreement, and
//! CLI plumbing.

use lotus::config::RunConfig;
use lotus::data::glue::generate_suite;
use lotus::memcount;
use lotus::models::presets::{encoder_small_cfg, llama_tiny_cfg};
use lotus::optim::Hyper;
use lotus::sim::finetune_task;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};

fn quick_cfg(steps: u64) -> SimRunCfg {
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;
    cfg
}

#[test]
fn every_method_trains_without_nan() {
    let cfg = quick_cfg(25);
    let methods = [
        Method::FullRank,
        Method::GaLore { interval: 10 },
        Method::LowRank,
        Method::LoRA,
        Method::ReLoRA { merge_every: 10 },
        Method::AdaRankGrad { interval: 10, decay: 0.8 },
        Method::Apollo { refresh_every: 10 },
        Method::Lotus { gamma: 0.02, eta: 5, t_min: 5 },
        Method::RsvdFixed { interval: 10 },
    ];
    for method in methods {
        let mut t = SimTrainer::new(&cfg, method, 3);
        let r = t.train(25);
        assert!(
            r.final_ppl.is_finite() && r.final_ppl > 1.0,
            "{}: ppl {}",
            method.name(),
            r.final_ppl
        );
        for (_, l) in &r.loss_curve {
            assert!(l.is_finite(), "{} produced NaN loss", method.name());
        }
    }
}

#[test]
fn projected_methods_use_less_state_than_full() {
    let cfg = quick_cfg(10);
    let full = SimTrainer::new(&cfg, Method::FullRank, 1).train(10).state_bytes;
    for method in [
        Method::GaLore { interval: 50 },
        Method::Lotus { gamma: 0.01, eta: 10, t_min: 10 },
        Method::Apollo { refresh_every: 50 },
    ] {
        let st = SimTrainer::new(&cfg, method, 1).train(10).state_bytes;
        assert!(st < full, "{}: {st} !< {full}", method.name());
    }
}

#[test]
fn lotus_switches_more_often_than_galore_under_stall() {
    // Table 3's qualitative claim: adaptive switching fires more often
    // than the (long) fixed interval once gradients stabilize.
    let cfg = quick_cfg(80);
    let galore = SimTrainer::new(&cfg, Method::GaLore { interval: 100 }, 5).train(80);
    let lotus =
        SimTrainer::new(&cfg, Method::Lotus { gamma: 0.04, eta: 10, t_min: 10 }, 5).train(80);
    assert!(
        lotus.stats.subspace_count >= galore.stats.subspace_count,
        "lotus {} vs galore {}",
        lotus.stats.subspace_count,
        galore.stats.subspace_count
    );
}

#[test]
fn measured_state_matches_analytic_model_for_galore() {
    // One (d×d) layer at rank r: measured LowRankAdam bytes == analytic.
    let (d, r) = (64usize, 8usize);
    let measured = lotus::optim::presets_state_bytes_probe(d, d, r, &Hyper::default());
    let analytic = memcount::layer_mem(memcount::Method::GaLore, d as u64, d as u64, r as u64, 4)
        .opt_state;
    assert_eq!(measured as u64, analytic);
}

#[test]
fn glue_suite_end_to_end_two_methods() {
    let enc = {
        let mut e = encoder_small_cfg();
        e.d_model = 64;
        e.n_layers = 2;
        e.d_ff = 128;
        e.seq_len = 32;
        e.vocab = 512;
        e
    };
    let suite = generate_suite(enc.vocab, enc.seq_len, 77);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
    // run two tasks × two methods (full suite is the bench's job)
    for task_name in ["SST2", "MRPC"] {
        let task = suite.iter().find(|t| t.name == task_name).unwrap();
        for method in [Method::FullRank, Method::Lotus { gamma: 0.05, eta: 5, t_min: 5 }] {
            let r = finetune_task(&enc, task, method, 4, 1, 8, &hyper, 9);
            assert!(r.metric.is_finite(), "{task_name}/{}", method.name());
            assert!(r.metric >= -100.0 && r.metric <= 100.0);
        }
    }
}

#[test]
fn run_config_drives_sim_trainer() {
    let toml = r#"
name = "integration"
steps = 12
batch = 4
lr = 0.003
[model]
preset = "llama-tiny"
[method]
name = "lotus"
rank = 8
gamma = 0.02
eta = 5
t_min = 5
"#;
    let cfg = RunConfig::from_toml(toml).unwrap();
    let sim_cfg = SimRunCfg {
        model: cfg.model,
        rank: cfg.method.rank,
        batch: cfg.batch,
        steps: cfg.steps,
        eval_every: cfg.steps,
        eval_batches: 2,
        hyper: cfg.hyper,
        seed: cfg.seed,
        coherence: cfg.coherence,
        quant: cfg.quant,
        clip_norm: cfg.faults.clip_norm,
    };
    let mut t = SimTrainer::new(&sim_cfg, cfg.method.method, cfg.seed);
    let report = t.train(cfg.steps);
    assert!(report.final_ppl.is_finite());
    assert_eq!(report.steps, 12);
}

#[test]
fn data_pipeline_feeds_consistent_shapes() {
    use lotus::data::batch::SyncBatcher;
    use lotus::data::corpus::CorpusGen;
    let cfg = llama_tiny_cfg();
    let mut b = SyncBatcher::new(CorpusGen::new(cfg.vocab, 1, 0.7), 4, cfg.seq_len);
    for _ in 0..3 {
        let batch = b.next();
        assert_eq!(batch.tokens.len(), 4 * cfg.seq_len);
        assert!(batch.tokens.iter().all(|&t| (t as usize) < cfg.vocab));
    }
}

#[test]
fn eta_model_reproduces_fig2_ordering_at_3b() {
    use lotus::models::presets::llama_paper_3b;
    use lotus::train::eta::{eta_seconds, EtaMethod};
    let shape = llama_paper_3b();
    let spf = 1e-11; // nominal; ordering is spf-invariant
    let tokens_step = 1u64 << 16;
    let total = 1u64 << 26;
    let galore = eta_seconds(
        EtaMethod::GaLore { refresh_every: 200.0 },
        &shape,
        512,
        tokens_step,
        total,
        spf,
    );
    let lotus = eta_seconds(
        EtaMethod::Lotus { refresh_every: 120.0, oversample: 8, power_iters: 1 },
        &shape,
        512,
        tokens_step,
        total,
        spf,
    );
    let apollo = eta_seconds(EtaMethod::Apollo, &shape, 512, tokens_step, total, spf);
    let adarank = eta_seconds(
        EtaMethod::AdaRankGrad { refresh_every: 200.0 },
        &shape,
        512,
        tokens_step,
        total,
        spf,
    );
    // Fig 2a ordering: Lotus fastest of the subspace methods; GaLore slowest.
    assert!(lotus < galore, "lotus {lotus} < galore {galore}");
    assert!(adarank < galore);
    assert!(apollo < galore);
}
