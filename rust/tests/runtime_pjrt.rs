#![cfg(feature = "pjrt")]

//! PJRT runtime integration: load real artifacts, execute them, and
//! cross-check the numerics against the Rust-native simulator (same
//! weights → same loss/gradients) and against the Rust optimizer math.
//!
//! Requires `make artifacts` (tiny config). Tests self-skip otherwise.

use lotus::models::presets::llama_tiny_cfg;
use lotus::runtime::convert::{literal_to_matrix, matrix_to_literal, tokens_to_literal};
use lotus::runtime::Engine;
use lotus::sim::SimModel;
use lotus::tensor::Matrix;
use lotus::train::HostParams;
use lotus::util::Rng;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn tiny_batch(seed: u64, batch: usize, seq: usize, vocab: usize) -> (Vec<u32>, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let toks = (0..batch * seq).map(|_| rng.below(vocab as u64) as u32).collect();
    let tgts = (0..batch * seq).map(|_| rng.below(vocab as u64) as u32).collect();
    (toks, tgts)
}

#[test]
fn fwdbwd_loss_matches_simulator() {
    let Some(engine) = engine() else { return };
    let cfg = llama_tiny_cfg();
    let mm = engine.manifest.config("tiny").unwrap().clone();
    assert_eq!(mm.config.d_model, cfg.d_model);

    let sim = SimModel::new(cfg, 42);
    let params = HostParams::from_sim(&sim);
    let (toks, tgts) = tiny_batch(7, mm.batch, cfg.seq_len, cfg.vocab);

    // PJRT loss
    let mut inputs = params.to_literals().unwrap();
    inputs.push(tokens_to_literal(&toks, mm.batch, cfg.seq_len).unwrap());
    inputs.push(tokens_to_literal(&tgts, mm.batch, cfg.seq_len).unwrap());
    let outs = engine.run("fwdbwd_tiny", &inputs).unwrap();
    let pjrt_loss = outs[0].get_first_element::<f32>().unwrap() as f64;

    // simulator loss on identical weights/batch
    let sim_loss = sim.loss(&toks, &tgts, mm.batch, cfg.seq_len);
    let rel = (pjrt_loss - sim_loss).abs() / sim_loss;
    assert!(rel < 2e-3, "pjrt {pjrt_loss} vs sim {sim_loss} (rel {rel})");
}

#[test]
fn fwdbwd_grads_match_simulator() {
    let Some(engine) = engine() else { return };
    let cfg = llama_tiny_cfg();
    let mm = engine.manifest.config("tiny").unwrap().clone();
    let sim = SimModel::new(cfg, 43);
    let params = HostParams::from_sim(&sim);
    let (toks, tgts) = tiny_batch(8, mm.batch, cfg.seq_len, cfg.vocab);

    let mut inputs = params.to_literals().unwrap();
    inputs.push(tokens_to_literal(&toks, mm.batch, cfg.seq_len).unwrap());
    inputs.push(tokens_to_literal(&tgts, mm.batch, cfg.seq_len).unwrap());
    let outs = engine.run("fwdbwd_tiny", &inputs).unwrap();

    let (_, sim_grads) = sim.loss_and_grad(&toks, &tgts, mm.batch, cfg.seq_len);

    // embed grad (param 0) and layer-0 wq grad (param 1)
    let g_embed = literal_to_matrix(&outs[1], cfg.vocab, cfg.d_model).unwrap();
    let rel_e = g_embed.sub(&sim_grads.embed).fro_norm() / sim_grads.embed.fro_norm();
    assert!(rel_e < 5e-3, "embed grad rel err {rel_e}");

    let g_wq = literal_to_matrix(&outs[2], cfg.d_model, cfg.d_model).unwrap();
    let rel_q = g_wq.sub(&sim_grads.layers[0].wq).fro_norm() / sim_grads.layers[0].wq.fro_norm();
    assert!(rel_q < 5e-3, "wq grad rel err {rel_q}");

    // ffn w2 grad: outputs are [loss, embed, wq, wk, wv, wo, w1, w3, w2, ...]
    let g_w2 = literal_to_matrix(&outs[8], cfg.d_ff, cfg.d_model).unwrap();
    let rel_w2 = g_w2.sub(&sim_grads.layers[0].w2).fro_norm() / sim_grads.layers[0].w2.fro_norm();
    assert!(rel_w2 < 5e-3, "w2 grad rel err {rel_w2}");
}

#[test]
fn lowrank_adam_artifact_matches_rust_math() {
    let Some(engine) = engine() else { return };
    let cfg = llama_tiny_cfg();
    let (m, n, r) = (cfg.d_model, cfg.d_ff, 16usize); // Left side 128x344
    let mut rng = Rng::new(9);
    let w = Matrix::randn(m, n, 1.0, &mut rng);
    let g = Matrix::randn(m, n, 1.0, &mut rng);
    // orthonormal P via rust QR
    let p = lotus::linalg::qr::orthonormalize(&Matrix::randn(m, r, 1.0, &mut rng));
    let mom_m = Matrix::zeros(r, n);
    let mom_v = Matrix::zeros(r, n);
    let d_init = Matrix::randn(r, n, 1.0, &mut rng).normalized();
    let (lr, scale, t) = (1e-3f32, 0.5f32, 3u64);

    let spec = engine.manifest.lowrank_adam_for("tiny", m, n).unwrap();
    let outs = engine
        .run(
            &spec.name.clone(),
            &[
                matrix_to_literal(&w).unwrap(),
                matrix_to_literal(&g).unwrap(),
                matrix_to_literal(&p).unwrap(),
                matrix_to_literal(&mom_m).unwrap(),
                matrix_to_literal(&mom_v).unwrap(),
                matrix_to_literal(&d_init).unwrap(),
                xla::Literal::scalar(t as f32),
                xla::Literal::scalar(lr),
                xla::Literal::scalar(scale),
            ],
        )
        .unwrap();

    // Rust reference: project, Adam::direction, lift, apply
    use lotus::optim::{Adam, Hyper};
    use lotus::projection::{Projection, Side};
    let proj = Projection { basis: p.clone(), side: Side::Left };
    let low = proj.down(&g);
    let mut rm = mom_m.clone();
    let mut rv = mom_v.clone();
    let mut dir = Matrix::zeros(r, n);
    let hyper = Hyper { lr, ..Default::default() };
    Adam::direction(&mut rm, &mut rv, &low, &hyper, t, &mut dir);
    let mut w_ref = w.clone();
    w_ref.axpy(-scale, &proj.up(&dir));

    let w_pjrt = literal_to_matrix(&outs[0], m, n).unwrap();
    let rel = w_pjrt.sub(&w_ref).fro_norm() / w_ref.fro_norm();
    assert!(rel < 1e-4, "w' rel err {rel}");

    // displacement output matches ‖normalize(low) − d_init‖
    let disp = outs[3].get_first_element::<f32>().unwrap();
    let expect = low.normalized().sub(&d_init).fro_norm();
    assert!((disp - expect).abs() / expect < 1e-3, "disp {disp} vs {expect}");
}

#[test]
fn rsvd_artifact_produces_orthonormal_capturing_basis() {
    let Some(engine) = engine() else { return };
    let cfg = llama_tiny_cfg();
    let (m, n) = (cfg.d_model, cfg.d_ff);
    let mut rng = Rng::new(10);
    // low-rank + noise gradient so capture is measurable
    let u = lotus::linalg::qr::orthonormalize(&Matrix::randn(m, 8, 1.0, &mut rng));
    let v = Matrix::randn(8, n, 1.0, &mut rng);
    let mut g = lotus::linalg::matmul(&u, &v);
    g.scale(5.0);
    g.axpy(1.0, &Matrix::randn(m, n, 0.1, &mut rng));

    let spec = engine.manifest.rsvd_for("tiny", m, n).unwrap();
    let rank = spec.rank.unwrap();
    let outs = engine
        .run(&spec.name.clone(), &[matrix_to_literal(&g).unwrap(), xla::Literal::scalar(5i32)])
        .unwrap();
    let p = literal_to_matrix(&outs[0], m, rank).unwrap();
    let oe = lotus::linalg::orthonormality_error(&p);
    assert!(oe < 1e-3, "orthonormality {oe}");
    // captures the planted subspace energy
    let cap = lotus::linalg::norms::captured_energy(&p, &g);
    assert!(cap > 0.85, "captured energy {cap}");
    // d_init is unit Frobenius
    let d = literal_to_matrix(&outs[1], rank, n).unwrap();
    assert!((d.fro_norm() - 1.0).abs() < 1e-3);
}

#[test]
fn adam_full_artifact_matches_rust_adam() {
    let Some(engine) = engine() else { return };
    let cfg = llama_tiny_cfg();
    let (vm, d) = (cfg.vocab, cfg.d_model);
    let mut rng = Rng::new(11);
    let w = Matrix::randn(vm, d, 1.0, &mut rng);
    let g = Matrix::randn(vm, d, 1.0, &mut rng);
    let z = Matrix::zeros(vm, d);
    let outs = engine
        .run(
            "adam_full_tiny_embed",
            &[
                matrix_to_literal(&w).unwrap(),
                matrix_to_literal(&g).unwrap(),
                matrix_to_literal(&z).unwrap(),
                matrix_to_literal(&z).unwrap(),
                xla::Literal::scalar(1.0f32),
                xla::Literal::scalar(0.01f32),
            ],
        )
        .unwrap();
    use lotus::optim::{Adam, Hyper, Optimizer};
    let mut adam = Adam::new(vm, d);
    adam.decoupled_wd = false;
    let mut w_ref = w.clone();
    adam.step(&mut w_ref, &g, &Hyper { lr: 0.01, weight_decay: 0.0, ..Default::default() }, 1);
    let w_pjrt = literal_to_matrix(&outs[0], vm, d).unwrap();
    let rel = w_pjrt.sub(&w_ref).fro_norm() / w_ref.fro_norm();
    assert!(rel < 1e-5, "rel {rel}");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(engine) = engine() else { return };
    assert_eq!(engine.cached_count(), 0);
    let _ = engine.executable("logits_tiny").unwrap();
    let _ = engine.executable("logits_tiny").unwrap();
    assert_eq!(engine.cached_count(), 1);
}
