//! Quantization engine: int8 block-codec properties (round-trip error
//! bound, zero preservation, typed NaN/Inf rejection, panic-free decode
//! of mangled bytes), quantized-wire worker-count invariance, int8-wire
//! loss drift vs the f32 baseline, and moment-quantized checkpoint
//! resume. The CI matrix re-runs this file under `LOTUS_THREADS=1` and
//! `LOTUS_THREADS=4` to pin thread-count determinism of the pooled
//! codec kernels.

use lotus::dist::{DistCfg, DistTrainer};
use lotus::models::presets::llama_tiny_cfg;
use lotus::quant::{Codec, QuantDtype, QuantError};
use lotus::sim::model::Params;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::util::Rng;

fn quick_cfg(steps: u64) -> SimRunCfg {
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;
    cfg.eval_every = 1_000_000;
    cfg.eval_batches = 2;
    cfg
}

fn lotus_switchy() -> Method {
    Method::Lotus { gamma: 0.9, eta: 3, t_min: 2 }
}

fn dist(workers: usize, shards: usize) -> DistCfg {
    DistCfg { workers, shards, quorum: 0.5 }
}

fn assert_params_identical(a: &Params, b: &Params, tag: &str) {
    assert_eq!(a.embed.data, b.embed.data, "{tag}: embed");
    assert_eq!(a.final_norm, b.final_norm, "{tag}: final_norm");
    for (i, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.wq.data, lb.wq.data, "{tag}: L{i}/wq");
        assert_eq!(la.wk.data, lb.wk.data, "{tag}: L{i}/wk");
        assert_eq!(la.wv.data, lb.wv.data, "{tag}: L{i}/wv");
        assert_eq!(la.wo.data, lb.wo.data, "{tag}: L{i}/wo");
        assert_eq!(la.w1.data, lb.w1.data, "{tag}: L{i}/w1");
        assert_eq!(la.w3.data, lb.w3.data, "{tag}: L{i}/w3");
        assert_eq!(la.w2.data, lb.w2.data, "{tag}: L{i}/w2");
        assert_eq!(la.norm1, lb.norm1, "{tag}: L{i}/norm1");
        assert_eq!(la.norm2, lb.norm2, "{tag}: L{i}/norm2");
    }
}

// ---------------------------------------------------------------------
// int8 block-codec properties (seeded fuzz, many shapes/blocks)
// ---------------------------------------------------------------------

#[test]
fn int8_roundtrip_error_bounded_by_half_scale() {
    let mut rng = Rng::new(0x51_0001);
    for case in 0..50u64 {
        let n = 1 + (rng.below(500) as usize);
        let block = 1 + (rng.below(100) as usize);
        let c = Codec::new(QuantDtype::Int8, block);
        let xs: Vec<f32> = (0..n)
            .map(|_| rng.normal_f32(0.0, 10.0_f32.powi((rng.below(7) as i32) - 3)))
            .collect();
        let mut bytes = Vec::new();
        c.encode_into(&xs, &mut bytes).unwrap();
        assert_eq!(bytes.len(), c.encoded_len(n), "case {case}");
        let mut back = vec![0.0f32; n];
        c.decode_into(&bytes, &mut back).unwrap();
        for (bi, chunk) in xs.chunks(block).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let scale = absmax / 127.0;
            for (j, x) in chunk.iter().enumerate() {
                let got = back[bi * block + j];
                let err = (x - got).abs();
                // round-to-nearest on x/scale: |err| <= scale/2 (+ float slop)
                assert!(
                    err <= scale * 0.5000002 + f32::EPSILON,
                    "case {case} block {bi} elem {j}: x={x} got={got} err={err} scale={scale}"
                );
            }
        }
    }
}

#[test]
fn int8_preserves_zeros_exactly() {
    let c = Codec::new(QuantDtype::Int8, 16);
    // mixed zeros inside live blocks + one all-zero block
    let mut xs = vec![0.0f32; 48];
    for (i, x) in xs.iter_mut().enumerate().take(16) {
        *x = if i % 3 == 0 { 0.0 } else { (i as f32) - 8.0 };
    }
    for (i, x) in xs.iter_mut().enumerate().skip(32) {
        *x = (i as f32) * 0.25;
    }
    let mut bytes = Vec::new();
    c.encode_into(&xs, &mut bytes).unwrap();
    let mut back = vec![1.0f32; 48];
    c.decode_into(&bytes, &mut back).unwrap();
    for (i, (x, b)) in xs.iter().zip(&back).enumerate() {
        if *x == 0.0 {
            assert_eq!(*b, 0.0, "zero at {i} must decode to exact zero");
        }
    }
    // the all-zero middle block decodes to exact zeros via a zero scale
    assert!(back[16..32].iter().all(|&b| b == 0.0));
}

#[test]
fn int8_rejects_nan_and_inf_with_typed_errors() {
    let c = Codec::new(QuantDtype::Int8, 8);
    for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
        let mut xs = vec![1.0f32; 20];
        xs[17] = bad;
        let mut bytes = Vec::new();
        assert_eq!(
            c.encode_into(&xs, &mut bytes),
            Err(QuantError::NonFinite { index: 17 }),
            "{bad}"
        );
        // the pooled encoder screens identically
        assert_eq!(
            c.encode_into_pooled(&xs, &mut bytes),
            Err(QuantError::NonFinite { index: 17 }),
            "{bad} (pooled)"
        );
    }
}

#[test]
fn int8_decode_of_mangled_bytes_never_panics() {
    let c = Codec::new(QuantDtype::Int8, 8);
    let mut rng = Rng::new(0x51_0002);
    let xs: Vec<f32> = (0..100).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut bytes = Vec::new();
    c.encode_into(&xs, &mut bytes).unwrap();
    let mut out = vec![0.0f32; xs.len()];
    // flip every byte in turn (corrupts scales and payload alike): the
    // decode must return Ok with *some* floats — garbage is caught one
    // layer up by the transfer checksum, never by a panic here
    for i in 0..bytes.len() {
        let mut mangled = bytes.clone();
        mangled[i] ^= 0xFF;
        c.decode_into(&mangled, &mut out).unwrap();
        c.decode_into_pooled(&mangled, &mut out).unwrap();
    }
    // wrong lengths are typed errors, not panics
    let err = c.decode_into(&bytes[..bytes.len() - 1], &mut out).unwrap_err();
    assert!(matches!(err, QuantError::Malformed { .. }));
    assert!(c.decode_into(&[], &mut out).is_err());
}

#[test]
fn encode_is_a_pure_function_of_input_bytes() {
    let mut rng = Rng::new(0x51_0003);
    let xs: Vec<f32> = (0..777).map(|_| rng.normal_f32(0.0, 3.0)).collect();
    for dtype in [QuantDtype::F32, QuantDtype::Bf16, QuantDtype::Int8] {
        let c = Codec::new(dtype, 64);
        let (mut a, mut b, mut p) = (Vec::new(), Vec::new(), Vec::new());
        c.encode_into(&xs, &mut a).unwrap();
        c.encode_into(&xs, &mut b).unwrap();
        c.encode_into_pooled(&xs, &mut p).unwrap();
        assert_eq!(a, b, "{dtype:?}: repeat encode");
        assert_eq!(a, p, "{dtype:?}: pooled vs serial encode");
    }
}

// ---------------------------------------------------------------------
// quantized wire: worker invariance, byte reduction, loss drift
// ---------------------------------------------------------------------

fn run_dist(cfg: &SimRunCfg, workers: usize) -> (lotus::dist::DistReport, Params) {
    let mut t = DistTrainer::new(cfg, lotus_switchy(), dist(workers, 4), 11).unwrap();
    let r = t.train(cfg.steps);
    (r, t.model().params.clone())
}

#[test]
fn quantized_wire_is_worker_count_invariant() {
    // Q = decode∘encode is applied at every tree edge, so the reduced
    // value is a pure function of the shard gradients — worker counts
    // 1/2/4 must agree bit-for-bit at bf16 and int8 wire dtypes.
    for wire in [QuantDtype::Bf16, QuantDtype::Int8] {
        let mut cfg = quick_cfg(8);
        cfg.quant.wire = wire;
        let (r1, p1) = run_dist(&cfg, 1);
        let (r2, p2) = run_dist(&cfg, 2);
        let (r4, p4) = run_dist(&cfg, 4);
        assert_eq!(r1.losses, r2.losses, "{wire:?}: N=2 losses diverged");
        assert_eq!(r1.losses, r4.losses, "{wire:?}: N=4 losses diverged");
        assert_eq!(r1.switch_steps, r4.switch_steps, "{wire:?}: switch schedule");
        assert_params_identical(&p1, &p2, "N=1 vs N=2");
        assert_params_identical(&p1, &p4, "N=1 vs N=4");
        // quantization must not stall training outright
        let head = (r1.losses[0] + r1.losses[1]) / 2.0;
        let tail = r1.losses[r1.losses.len() - 2..].iter().sum::<f64>() / 2.0;
        assert!(tail < head, "{wire:?}: no learning: head {head} tail {tail}");
    }
}

#[test]
fn f32_wire_codec_matches_the_unquantized_path_bitwise() {
    // wire = f32 must be a true no-op: same bytes charged, same weights
    // as the default config (which routes through the same reducer)
    let cfg = quick_cfg(6);
    let (r_base, p_base) = run_dist(&cfg, 4);
    let mut cfg_f32 = quick_cfg(6);
    cfg_f32.quant.wire = QuantDtype::F32;
    let (r_f32, p_f32) = run_dist(&cfg_f32, 4);
    assert_eq!(r_base.losses, r_f32.losses);
    assert_eq!(r_base.comm.lowrank_bytes, r_f32.comm.lowrank_bytes);
    assert_params_identical(&p_base, &p_f32, "default vs explicit f32 wire");
}

#[test]
fn int8_wire_cuts_bytes_3x_and_stays_close_to_f32_loss() {
    let cfg = quick_cfg(10);
    let (r_f32, _) = run_dist(&cfg, 4);
    let mut cfg_q = quick_cfg(10);
    cfg_q.quant.wire = QuantDtype::Int8;
    let (r_int8, _) = run_dist(&cfg_q, 4);
    let moved = |r: &lotus::dist::DistReport| {
        r.comm.lowrank_bytes + r.comm.refresh_dense_bytes + r.comm.other_dense_bytes
    };
    let ratio = moved(&r_f32) as f64 / moved(&r_int8) as f64;
    assert!(ratio >= 3.0, "int8 wire reduction {ratio:.2}x < 3x");
    // int8 gradients perturb the trajectory but must not wreck it: the
    // final losses stay within 15% of each other on this tiny run
    let lf = r_f32.losses.last().unwrap();
    let lq = r_int8.losses.last().unwrap();
    assert!(
        (lf - lq).abs() / lf.abs() < 0.15,
        "int8-wire final loss {lq} drifted >15% from f32 {lf}"
    );
    // bf16 wire halves the bytes
    let mut cfg_b = quick_cfg(10);
    cfg_b.quant.wire = QuantDtype::Bf16;
    let (r_bf16, _) = run_dist(&cfg_b, 4);
    let bratio = moved(&r_f32) as f64 / moved(&r_bf16) as f64;
    assert!((1.9..=2.1).contains(&bratio), "bf16 wire ratio {bratio:.2}x != ~2x");
}

// ---------------------------------------------------------------------
// quantized optimizer moments: training + checkpoint resume
// ---------------------------------------------------------------------

#[test]
fn moment_quantized_checkpoints_resume_bit_identically() {
    // the checkpoint stores the dequantized f32 mirror of the moment
    // carriers; since quantization is re-applied deterministically after
    // every update, a resumed run must replay the uninterrupted one
    let dir = std::env::temp_dir().join(format!("lotus_quant_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for state in [QuantDtype::Bf16, QuantDtype::Int8] {
        let mut cfg = quick_cfg(10);
        cfg.quant.state = state;
        let method = lotus_switchy();
        // uninterrupted run
        let mut full = SimTrainer::new(&cfg, method, 7);
        full.train(10);
        // interrupted at step 5 + resumed
        let mut head = SimTrainer::new(&cfg, method, 7);
        head.train(5);
        let path = dir.join(format!("state_{}.ckpt", state.as_str()));
        let path = path.to_str().unwrap();
        head.save_checkpoint(path).unwrap();
        let mut tail = SimTrainer::new(&cfg, method, 7);
        tail.load_checkpoint(path).unwrap();
        tail.train(5);
        assert_params_identical(
            &full.model().params,
            &tail.model().params,
            &format!("{state:?} resume"),
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quantized_moments_still_learn() {
    // loss_curve samples t=1 and every 10th step, so a 20-step run
    // yields (1, loss_head) and (20, loss_tail)
    let base = quick_cfg(20);
    let run = |state: QuantDtype| {
        let mut cfg = base;
        cfg.quant.state = state;
        let mut t = SimTrainer::new(&cfg, lotus_switchy(), 3);
        let r = t.train(20);
        (r.loss_curve.first().unwrap().1, r.loss_curve.last().unwrap().1)
    };
    for state in [QuantDtype::Bf16, QuantDtype::Int8] {
        let (first, last) = run(state);
        assert!(last < first, "{state:?} moments: loss {first} -> {last} did not fall");
    }
}
