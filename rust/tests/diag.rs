//! Run-diagnostics engine contracts (ISSUE 9):
//!
//! * probes at `--probe-every 1` emit capture/residual/noise records for
//!   every layer × matrix, deterministically (two seeded runs are
//!   byte-identical modulo `"wall"` — CI repeats this under
//!   LOTUS_THREADS=1 and 4);
//! * probe-off streams carry no new record types and the step-record
//!   key set is unchanged (byte-identity with pre-probe runs);
//! * `analyze` renders byte-stable switch-quality / cadence tables from
//!   the same stream;
//! * `--prom-out` snapshots parse as Prometheus text, atomically (no
//!   stale `.tmp` left behind), and feed `lotus top`'s renderer;
//! * `--clip-norm` bounds the full gradient and emits typed `clipped`
//!   records upstream of the spike detector;
//! * ring trace mode keeps only the newest N complete events.
//!
//! The sinks and probe gates are process-global, so every test
//! serializes on `LOCK`.

use std::sync::Mutex;

use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer, MAT_NAMES};
use lotus::telemetry::{self, analyze, diag};
use lotus::util::json::{self, JsonValue};

static LOCK: Mutex<()> = Mutex::new(());

fn tmp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("lotus_diag_{}_{name}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn sim_cfg(steps: u64) -> SimRunCfg {
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;
    cfg.eval_every = steps;
    cfg.eval_batches = 1;
    cfg
}

fn lotus_method() -> Method {
    // small gaps so subspace switches fire within a short run
    Method::Lotus { gamma: 0.5, eta: 5, t_min: 5 }
}

/// Run a seeded sim with the metrics sink on `path` (probes at cadence
/// `probe_every`; 0 = off), returning the emitted JSONL text. Resets
/// all diagnostic gates before returning.
fn run_probed(path: &str, cfg: &SimRunCfg, probe_every: u64) -> String {
    telemetry::install_metrics(path).expect("install metrics sink");
    if probe_every > 0 {
        diag::set_probe_every(probe_every);
        diag::set_probes_enabled(true);
    }
    let mut t = SimTrainer::new(cfg, lotus_method(), cfg.seed);
    let r = t.train(cfg.steps);
    assert!(r.final_ppl.is_finite());
    telemetry::finish().expect("flush metrics sink");
    let text = std::fs::read_to_string(path).expect("metrics file");
    let _ = std::fs::remove_file(path);
    text
}

/// Drop `"log"` records, strip the quarantined `"wall"` key,
/// reserialize canonically.
fn normalize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let mut v = json::parse(line).expect("metrics line parses");
        if v.get("type").as_str() == Some("log") {
            continue;
        }
        if let JsonValue::Obj(ref mut m) = v {
            m.remove("wall");
        }
        out.push(v.to_string());
    }
    out
}

fn records_of<'a>(text: &'a str, kind: &str) -> Vec<JsonValue> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| json::parse(l).unwrap())
        .filter(|v| v.get("type").as_str() == Some(kind))
        .collect()
}

#[test]
fn probes_at_k1_cover_every_layer_matrix_and_are_deterministic() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let cfg = sim_cfg(12);
    let a = run_probed(&tmp_path("probe_a.jsonl"), &cfg, 1);
    let b = run_probed(&tmp_path("probe_b.jsonl"), &cfg, 1);
    assert_eq!(normalize(&a), normalize(&b), "probed streams diverged");
    // the probed stream still validates end to end
    assert_eq!(telemetry::check_metrics(&a).unwrap(), a.lines().count());

    let probes = records_of(&a, "probe");
    assert!(!probes.is_empty());
    for p in &probes {
        let cap = p.get("capture").as_f64().expect("capture ratio");
        let res = p.get("residual").as_f64().expect("residual energy");
        assert!((0.0..=1.0).contains(&cap), "capture {cap} outside [0,1]");
        assert!((res - (1.0 - cap * cap)).abs() < 1e-9, "residual != 1 - capture^2");
        assert!(p.get("noise_scale").as_f64().expect("noise scale") >= 0.0);
        assert!(p.get("age").as_f64().is_some());
        assert_eq!(p.get("rank").as_f64(), Some(16.0));
        // Lotus exposes its displacement threshold, so margin is numeric
        assert!(p.get("margin").as_f64().is_some(), "lotus probes carry a margin");
    }
    // at k=1 every layer × matrix slot reports every step
    let n_layers = cfg.model.n_layers;
    for li in 0..n_layers {
        for mat in MAT_NAMES {
            let n = probes
                .iter()
                .filter(|p| {
                    p.get("layer").as_f64() == Some(li as f64)
                        && p.get("mat").as_str() == Some(mat)
                })
                .count();
            assert_eq!(n, 12, "L{li}/{mat}: {n} probe records, want one per step");
        }
    }
}

#[test]
fn probe_off_streams_carry_no_new_record_types() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let cfg = sim_cfg(10);
    let off = run_probed(&tmp_path("off.jsonl"), &cfg, 0);
    assert!(records_of(&off, "probe").is_empty(), "probe records with probes off");
    assert!(records_of(&off, "clipped").is_empty(), "clip records with clipping off");
    // step-record schema is exactly the pre-diagnostics key set
    for s in records_of(&off, "step") {
        let JsonValue::Obj(ref m) = s else { panic!("step record is not an object") };
        let keys: Vec<&str> = m.keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            ["displacement", "grad_norm", "loss", "step", "switches", "type", "wall"],
        );
    }
    // a second probe-off run is byte-identical modulo wall — the
    // diagnostics engine leaves legacy streams untouched
    let off2 = run_probed(&tmp_path("off2.jsonl"), &cfg, 0);
    assert_eq!(normalize(&off), normalize(&off2));
}

#[test]
fn analyze_renders_stable_switch_quality_and_cadence_tables() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let cfg = sim_cfg(12);
    let a = run_probed(&tmp_path("an_a.jsonl"), &cfg, 1);
    let b = run_probed(&tmp_path("an_b.jsonl"), &cfg, 1);
    let ra = analyze::parse_run(&a).expect("parse run");
    let rb = analyze::parse_run(&b).expect("parse run");
    assert_eq!(ra.steps.len(), 12);
    assert!(!ra.switches.is_empty(), "short-gap Lotus run must switch");
    assert!(!ra.probes.is_empty());

    // pure functions of a deterministic stream: tables are bit-identical
    // run to run (CI re-checks this under LOTUS_THREADS=1 and 4)
    assert_eq!(analyze::switch_quality_table(&ra), analyze::switch_quality_table(&rb));
    assert_eq!(analyze::cadence_table(&ra), analyze::cadence_table(&rb));
    assert_eq!(analyze::probe_table(&ra), analyze::probe_table(&rb));

    let sq = analyze::switch_quality_table(&ra);
    assert!(sq.contains("cap_pre") && sq.contains("cap_post"), "{sq}");
    let cad = analyze::cadence_table(&ra);
    assert!(cad.contains("mean_lifetime"), "{cad}");
    // self-comparison reports zero delta on the loss metrics
    let cmp = analyze::compare_table(&ra, &ra);
    assert!(cmp.contains("final_loss"), "{cmp}");
    assert!(cmp.contains("+0.0%"), "self-compare must show zero deltas:\n{cmp}");
}

#[test]
fn prom_snapshot_parses_atomically_and_feeds_top() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let prom_path = tmp_path("run.prom");
    let metrics_path = tmp_path("prom_run.jsonl");
    telemetry::install_metrics(&metrics_path).expect("install metrics sink");
    diag::install_prom(&prom_path).expect("install prom snapshot");
    diag::set_probe_every(1);
    diag::set_probes_enabled(true);
    let cfg = sim_cfg(8);
    let mut t = SimTrainer::new(&cfg, lotus_method(), cfg.seed);
    t.train(8);
    telemetry::finish().expect("flush sinks");
    let _ = std::fs::remove_file(&metrics_path);

    // atomic rewrite: the final snapshot exists, the .tmp does not
    assert!(!std::path::Path::new(&format!("{prom_path}.tmp")).exists(), "stale .tmp");
    let text = std::fs::read_to_string(&prom_path).expect("prom snapshot");
    let _ = std::fs::remove_file(&prom_path);
    let prom = analyze::parse_prom_text(&text).expect("prometheus text parses");
    assert!(!prom.is_empty());
    let get = |k: &str| prom.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
    assert_eq!(get("lotus_train_step"), Some(8.0));
    assert!(get("lotus_train_loss_micro").unwrap_or(0.0) > 0.0);
    // per-matrix probe gauges made it to the exposition
    assert!(
        prom.iter().any(|(n, _)| n.starts_with("lotus_diag_capture_micro_L0_wq")),
        "missing capture gauge: {:?}",
        prom.iter().map(|(n, _)| n).take(20).collect::<Vec<_>>()
    );
    // and the dashboard renders a per-layer table from them
    let top = analyze::render_top(&prom);
    assert!(top.contains("loss"), "{top}");
    assert!(top.contains("L0"), "per-layer rows missing:\n{top}");
}

#[test]
fn clip_norm_emits_typed_records_and_bounds_grad_norm() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut cfg = sim_cfg(10);
    cfg.clip_norm = 1e-3; // far below any real gradient norm
    let path = tmp_path("clip.jsonl");
    telemetry::install_metrics(&path).expect("install metrics sink");
    let mut t = SimTrainer::new(&cfg, lotus_method(), cfg.seed);
    let r = t.train(10);
    assert_eq!(r.clipped_steps, 10);
    telemetry::finish().expect("flush metrics sink");
    let text = std::fs::read_to_string(&path).expect("metrics file");
    let _ = std::fs::remove_file(&path);

    let clipped = records_of(&text, "clipped");
    assert_eq!(clipped.len(), 10, "one clipped record per clipped step");
    for c in &clipped {
        assert!(c.get("grad_norm").as_f64().unwrap() > 1e-3, "pre-clip norm recorded");
        assert_eq!(c.get("clip_norm").as_f64(), Some(1e-3));
        assert!(c.get("anomaly").as_f64().unwrap() > 0.0);
    }
    // the step records report the post-clip norm (matrices are a subset
    // of the clipped full gradient, so ≤ threshold modulo f32 rounding)
    for s in records_of(&text, "step") {
        let gn = s.get("grad_norm").as_f64().unwrap();
        assert!(gn <= 1e-3 * 1.001, "step grad_norm {gn} exceeds the clip threshold");
    }
    // the analyzer picks the events up as an anomaly flag
    let run = analyze::parse_run(&text).unwrap();
    assert_eq!(run.clipped.len(), 10);
    let flags = analyze::anomaly_flags(&run);
    assert!(flags.iter().any(|f| f.contains("clip")), "{flags:?}");
}

#[test]
fn ring_trace_mode_keeps_only_the_newest_events() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let cap = 64usize;
    let ring_path = tmp_path("ring.json");
    telemetry::install_trace_with(&ring_path, cap);
    let cfg = sim_cfg(8);
    let mut t = SimTrainer::new(&cfg, lotus_method(), cfg.seed);
    t.train(8);
    telemetry::finish().expect("write ring trace");
    let ring_text = std::fs::read_to_string(&ring_path).expect("ring trace");
    let _ = std::fs::remove_file(&ring_path);
    let (ring_events, _) = telemetry::check_trace(&ring_text).expect("ring trace validates");
    assert_eq!(ring_events, cap, "ring holds exactly its capacity once saturated");

    // an unbounded trace of the same run holds far more — the ring kept
    // the newest slice, which must include the final Eval span
    let full_path = tmp_path("full.json");
    telemetry::install_trace(&full_path);
    let mut t = SimTrainer::new(&cfg, lotus_method(), cfg.seed);
    t.train(8);
    telemetry::finish().expect("write full trace");
    let full_text = std::fs::read_to_string(&full_path).expect("full trace");
    let _ = std::fs::remove_file(&full_path);
    let (full_events, _) = telemetry::check_trace(&full_text).expect("full trace validates");
    assert!(full_events > cap, "full trace ({full_events}) should dwarf the ring ({cap})");
    assert!(ring_text.contains("\"name\":\"eval\""), "newest events must survive");
}

#[test]
fn report_check_rejects_truncated_tails_with_typed_errors() {
    // no sink needed — pure text checks (satellite 3's CLI surface)
    let good = concat!(
        "{\"type\":\"step\",\"step\":1,\"loss\":4.0,\"wall\":{}}\n",
        "{\"type\":\"registry\",\"wall\":{}}\n",
    );
    assert_eq!(telemetry::check_metrics(good).unwrap(), 2);
    // a stream that stops mid-write fails with TruncatedTail…
    let cut = &good[..good.len() - 1];
    match telemetry::check_metrics(cut) {
        Err(telemetry::CheckError::TruncatedTail) => {}
        other => panic!("want TruncatedTail, got {other:?}"),
    }
    // …and a complete stream that never flushed its registry record
    // fails with MissingRegistry naming the last record type
    let unfinished = "{\"type\":\"step\",\"step\":1,\"loss\":4.0,\"wall\":{}}\n";
    match telemetry::check_metrics(unfinished) {
        Err(telemetry::CheckError::MissingRegistry { last_type }) => {
            assert_eq!(last_type, "step");
        }
        other => panic!("want MissingRegistry, got {other:?}"),
    }
}
