//! Distributed data-parallel engine: worker-count bit-identity, subspace
//! consensus determinism, checkpoint re-sharding across world sizes, and
//! comm-volume accounting against the analytic model.
//!
//! The load-bearing claim (ISSUE 2 acceptance): an N=4 worker run is
//! **bit-identical** to the N=1 run on the same total batch — same
//! per-step losses, same switch steps, same final weights. The CI matrix
//! re-runs this file under `LOTUS_THREADS=1` and `LOTUS_THREADS=4` to
//! pin thread-count determinism as well.

use lotus::dist::{DistCfg, DistTrainer, Topology};
use lotus::memcount;
use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::model::Params;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};

fn quick_cfg(steps: u64) -> SimRunCfg {
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;
    cfg.eval_every = 1_000_000; // no mid-run evals; final eval only
    cfg.eval_batches = 2;
    cfg
}

fn lotus_switchy() -> Method {
    // aggressive thresholds so consensus switches fire within short runs
    Method::Lotus { gamma: 0.9, eta: 3, t_min: 2 }
}

fn dist(workers: usize, shards: usize) -> DistCfg {
    DistCfg { workers, shards, quorum: 0.5 }
}

fn assert_params_identical(a: &Params, b: &Params, tag: &str) {
    assert_eq!(a.embed.data, b.embed.data, "{tag}: embed");
    assert_eq!(a.final_norm, b.final_norm, "{tag}: final_norm");
    assert_eq!(a.layers.len(), b.layers.len(), "{tag}: layer count");
    for (i, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.wq.data, lb.wq.data, "{tag}: L{i}/wq");
        assert_eq!(la.wk.data, lb.wk.data, "{tag}: L{i}/wk");
        assert_eq!(la.wv.data, lb.wv.data, "{tag}: L{i}/wv");
        assert_eq!(la.wo.data, lb.wo.data, "{tag}: L{i}/wo");
        assert_eq!(la.w1.data, lb.w1.data, "{tag}: L{i}/w1");
        assert_eq!(la.w3.data, lb.w3.data, "{tag}: L{i}/w3");
        assert_eq!(la.w2.data, lb.w2.data, "{tag}: L{i}/w2");
        assert_eq!(la.norm1, lb.norm1, "{tag}: L{i}/norm1");
        assert_eq!(la.norm2, lb.norm2, "{tag}: L{i}/norm2");
    }
}

#[test]
fn dist_worker_counts_are_bit_identical() {
    // Same total batch (4 canonical shards), worker counts 1/2/4: the
    // losses, switch schedule and final weights must agree bit-for-bit.
    let cfg = quick_cfg(10);
    let run = |workers: usize| {
        let mut t = DistTrainer::new(&cfg, lotus_switchy(), dist(workers, 4), 11).unwrap();
        let r = t.train(10);
        (r, t.model().params.clone())
    };
    let (r1, p1) = run(1);
    let (r2, p2) = run(2);
    let (r4, p4) = run(4);
    assert_eq!(r1.losses, r2.losses, "N=2 losses diverged from N=1");
    assert_eq!(r1.losses, r4.losses, "N=4 losses diverged from N=1");
    assert_eq!(r1.switch_steps, r4.switch_steps, "switch schedule diverged");
    assert_eq!(r1.stats.subspace_count, r4.stats.subspace_count);
    assert_eq!(r1.final_ppl, r4.final_ppl, "final ppl diverged");
    assert_params_identical(&p1, &p2, "N=1 vs N=2");
    assert_params_identical(&p1, &p4, "N=1 vs N=4");
    // training must actually go somewhere (first two vs last two steps)
    let head = (r1.losses[0] + r1.losses[1]) / 2.0;
    let tail = (r1.losses[8] + r1.losses[9]) / 2.0;
    assert!(tail < head, "no learning: head {head} tail {tail}");
    // the wire sees traffic only when shards cross workers
    assert_eq!(r1.comm.lowrank_bytes, 0, "N=1 moves no bytes");
    assert!(r4.comm.lowrank_bytes > r2.comm.lowrank_bytes);
    // consensus switching engaged beyond the init fits
    assert!(r4.consensus.triggered > 0, "no consensus switches fired");
}

#[test]
fn dist_single_shard_matches_sim_trainer_exactly() {
    // With one shard and one worker the dist engine must reproduce the
    // classic SimTrainer bit-for-bit: same data stream, same per-matrix
    // optimizers and switching decisions, same weights.
    let cfg = quick_cfg(11);
    let method = Method::Lotus { gamma: 0.5, eta: 3, t_min: 2 };
    let mut sim = SimTrainer::new(&cfg, method, 5);
    let sim_report = sim.train(11);
    let mut dd = DistTrainer::new(&cfg, method, dist(1, 1), 5).unwrap();
    let dist_report = dd.train(11);
    assert_params_identical(&sim.model().params, &dd.model().params, "sim vs dist");
    assert_eq!(sim_report.final_ppl, dist_report.final_ppl, "eval ppl");
    assert_eq!(sim_report.stats.subspace_count, dist_report.stats.subspace_count);
    // loss curve samples (t=1, t=10) must match exactly
    for ((ts, ls), (td, ld)) in sim_report.loss_curve.iter().zip(&dist_report.loss_curve) {
        assert_eq!(ts, td);
        assert_eq!(ls, ld, "loss at step {ts}");
    }
}

#[test]
fn dist_single_shard_matches_sim_trainer_for_adarankgrad() {
    // The rank-decay method runs the same schedule in both entry points
    // (the consensus refresh refits at the current rank and applies the
    // decay after the step, exactly like the event-driven path), so the
    // S=1 dist run must reproduce the sim trainer bit-for-bit through
    // several decays.
    let cfg = quick_cfg(12);
    let method = Method::AdaRankGrad { interval: 4, decay: 0.5 };
    let mut sim = SimTrainer::new(&cfg, method, 6);
    let sim_report = sim.train(12);
    let mut dd = DistTrainer::new(&cfg, method, dist(1, 1), 6).unwrap();
    let dist_report = dd.train(12);
    assert_params_identical(&sim.model().params, &dd.model().params, "adarank sim vs dist");
    assert_eq!(sim_report.final_ppl, dist_report.final_ppl, "eval ppl");
    assert_eq!(
        sim_report.stats.subspace_count, dist_report.stats.subspace_count,
        "subspace sequence diverged"
    );
    // the decay actually engaged (interval switches → rank retirements)
    assert!(sim_report.stats.subspace_count > 14, "{:?}", sim_report.stats);
}

#[test]
fn dist_consensus_refresh_is_deterministic() {
    // Two identical N=4 runs: identical consensus telemetry, switch
    // schedule and comm accounting (the lockstep-RNG refresh claim).
    let cfg = quick_cfg(9);
    let run = || {
        let mut t = DistTrainer::new(&cfg, lotus_switchy(), dist(4, 4), 23).unwrap();
        let r = t.train(9);
        (r, t.model().params.clone())
    };
    let (ra, pa) = run();
    let (rb, pb) = run();
    assert_eq!(ra.losses, rb.losses);
    assert_eq!(ra.switch_steps, rb.switch_steps);
    assert_eq!(ra.consensus, rb.consensus);
    assert_eq!(ra.comm, rb.comm);
    assert_params_identical(&pa, &pb, "repeat run");
    assert!(ra.consensus.triggered > 0, "consensus must engage in this config");
    assert!(ra.comm.refresh_dense_bytes > 0, "refreshes move dense gradients");
}

#[test]
fn dist_fixed_interval_consensus_is_unanimous() {
    // GaLore-style fixed interval through the consensus machinery: every
    // shard votes switch at the same steps, so rounds are unanimous and
    // the switch schedule matches the single-worker semantics.
    let cfg = quick_cfg(10);
    let mut t =
        DistTrainer::new(&cfg, Method::RsvdFixed { interval: 4 }, dist(4, 4), 3).unwrap();
    let r = t.train(10);
    // init at t=1, then interval switches at t=5 and t=9
    assert_eq!(r.switch_steps, vec![1, 5, 9]);
    // 14 projected matrices × (1 init + 2 interval)
    assert_eq!(r.stats.subspace_count, 42, "{:?}", r.stats);
    assert_eq!(r.consensus.unanimous, r.consensus.rounds, "interval votes are lockstep");
    assert_eq!(r.consensus.triggered, 28, "two consensus switches per matrix");
}

#[test]
fn dist_comm_accounting_matches_analytic_model() {
    // Measured wire bytes must equal the analytic model exactly:
    // per step, per projected matrix, 2 legs × cross-edges × payload.
    let steps = 5u64;
    let cfg = quick_cfg(steps);
    let mut t =
        DistTrainer::new(&cfg, Method::RsvdFixed { interval: 100 }, dist(4, 4), 3).unwrap();
    let r = t.train(steps);

    let edges = Topology::new(4, 4).cross_edges();
    assert_eq!(edges, 3);
    let rank = cfg.rank as u64;
    let mut low_payload = 0u64;
    let mut dense_payload = 0u64;
    for (m, n) in lotus::sim::trainer::layer_matrix_shapes(&cfg.model) {
        let (m, n) = (m as u64, n as u64);
        low_payload += memcount::allreduce_layer_bytes(memcount::Method::Lotus, m, n, rank, 4);
        dense_payload += m * n * 4;
    }
    let n_layers = cfg.model.n_layers as u64;
    low_payload *= n_layers;
    dense_payload *= n_layers;

    // steady-state low-rank traffic: every step reduces every projected
    // matrix once
    assert_eq!(r.comm.lowrank_bytes, steps * 2 * edges * low_payload);
    // dense baseline for those same reductions
    assert_eq!(r.comm.dense_equiv_bytes, steps * 2 * edges * dense_payload);
    // exactly one dense refresh round (the init fit at t=1)
    assert_eq!(r.comm.refresh_dense_bytes, 2 * edges * dense_payload);
    // embedding + norm vectors are dense every step
    let vocab = cfg.model.vocab as u64;
    let d = cfg.model.d_model as u64;
    let other_payload = (vocab * d + (2 * n_layers + 1) * d) * 4;
    assert_eq!(r.comm.other_dense_bytes, steps * 2 * edges * other_payload);
    // structural saving: min(m,n)/r = d_model/rank for every tiny-model
    // matrix → the steady ratio is exactly (m/r)×
    let expect = (cfg.model.d_model / cfg.rank) as f64;
    assert!(
        (r.comm.steady_reduction_vs_dense() - expect).abs() < 1e-9,
        "steady ratio {} != {expect}",
        r.comm.steady_reduction_vs_dense()
    );
}

#[test]
fn dist_checkpoint_resharding_across_world_sizes() {
    // Save at N=4, resume at N=1 and N=2: subsequent losses and weights
    // must be bit-identical to the uninterrupted N=4 run.
    let cfg = quick_cfg(11);
    let method = lotus_switchy();
    let dir = std::env::temp_dir().join("lotus_dist_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("n4.ckpt");

    let mut a = DistTrainer::new(&cfg, method, dist(4, 4), 7).unwrap();
    let _ = a.train(6);
    a.save_checkpoint(&path).unwrap();
    assert_eq!(a.current_step(), 6);
    let cont = a.train(5); // steps 7..=11, uninterrupted

    for workers in [1usize, 2] {
        let mut b = DistTrainer::new(&cfg, method, dist(workers, 4), 7).unwrap();
        let step = b.load_checkpoint(&path).unwrap();
        assert_eq!(step, 6, "resume step");
        let resumed = b.train(5);
        assert_eq!(resumed.losses, cont.losses, "losses after resume at N={workers}");
        assert_eq!(resumed.final_ppl, cont.final_ppl, "ppl after resume at N={workers}");
        assert_params_identical(
            &a.model().params,
            &b.model().params,
            &format!("resume at N={workers}"),
        );
    }

    // a different shard decomposition is rejected (it changes the math)
    let mut c = DistTrainer::new(&cfg, method, dist(2, 2), 7).unwrap();
    assert!(c.load_checkpoint(&path).is_err(), "shard-count mismatch must fail");
    let _ = std::fs::remove_file(path);
}

#[test]
fn dist_fullrank_baseline_records_dense_traffic() {
    // The dense baseline trains through the same engine (that is what
    // the bench compares against) and moves only dense bytes.
    let cfg = quick_cfg(6);
    let mut t = DistTrainer::new(&cfg, Method::FullRank, dist(4, 4), 19).unwrap();
    let r = t.train(6);
    assert_eq!(r.comm.lowrank_bytes, 0);
    assert_eq!(r.comm.refresh_dense_bytes, 0);
    assert!(r.comm.other_dense_bytes > 0);
    assert!(r.losses.iter().all(|l| l.is_finite()));
    let head = (r.losses[0] + r.losses[1]) / 2.0;
    let tail = (r.losses[4] + r.losses[5]) / 2.0;
    assert!(tail < head, "baseline does not learn: head {head} tail {tail}");
    // and it is worker-count invariant too
    let mut t1 = DistTrainer::new(&cfg, Method::FullRank, dist(1, 4), 19).unwrap();
    let r1 = t1.train(6);
    assert_eq!(r.losses, r1.losses);
    assert_params_identical(&t.model().params, &t1.model().params, "full-rank N=4 vs N=1");
}
