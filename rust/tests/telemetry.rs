//! Telemetry subsystem contracts:
//!
//! * determinism — two identical seeded sim runs emit byte-identical
//!   metrics JSONL once the quarantined `"wall"` blocks (and free-text
//!   log records) are stripped;
//! * the Chrome trace of a sim run validates and covers the span kinds
//!   the ISSUE requires (≥ 6 distinct);
//! * the registry survives concurrent get-or-register from 8 threads;
//! * `digest_metrics` renders a byte-stable report (golden output).
//!
//! The sinks are process-global, so tests that install them serialize
//! on `LOCK`.

use std::sync::Mutex;

use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::telemetry;
use lotus::util::json::{self, JsonValue};

static LOCK: Mutex<()> = Mutex::new(());

fn tmp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("lotus_telemetry_{}_{name}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn sim_cfg(steps: u64) -> SimRunCfg {
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;
    cfg.eval_every = steps; // one mid-run eval + the final one
    cfg.eval_batches = 1;
    cfg
}

fn lotus_method() -> Method {
    // small gaps so subspace switches actually fire within a short run
    Method::Lotus { gamma: 0.5, eta: 5, t_min: 5 }
}

/// Run a seeded sim with the metrics sink on `path`, returning the
/// emitted JSONL text.
fn run_with_metrics(path: &str) -> String {
    telemetry::install_metrics(path).expect("install metrics sink");
    let cfg = sim_cfg(12);
    let mut t = SimTrainer::new(&cfg, lotus_method(), cfg.seed);
    let r = t.train(12);
    assert!(r.final_ppl.is_finite());
    telemetry::finish().expect("flush metrics sink");
    let text = std::fs::read_to_string(path).expect("metrics file");
    let _ = std::fs::remove_file(path);
    text
}

/// Strip the wall-clock quarantine: drop `"log"` records, remove the
/// `"wall"` key from the rest, reserialize (BTreeMap-backed objects, so
/// serialization is key-sorted and canonical).
fn normalize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let mut v = json::parse(line).expect("metrics line parses");
        if v.get("type").as_str() == Some("log") {
            continue;
        }
        if let JsonValue::Obj(ref mut m) = v {
            m.remove("wall");
        }
        out.push(v.to_string());
    }
    out
}

#[test]
fn seeded_runs_emit_identical_metrics_modulo_wall() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let a = run_with_metrics(&tmp_path("det_a.jsonl"));
    let b = run_with_metrics(&tmp_path("det_b.jsonl"));
    assert_eq!(normalize(&a), normalize(&b), "seeded metrics streams diverged");

    // the stream carries the subspace-dynamics instrumentation
    assert_eq!(telemetry::check_metrics(&a).unwrap(), a.lines().count());
    let steps: Vec<JsonValue> = a
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| json::parse(l).unwrap())
        .filter(|v| v.get("type").as_str() == Some("step"))
        .collect();
    assert_eq!(steps.len(), 12, "one step record per training step");
    for s in &steps {
        assert!(s.get("loss").as_f64().is_some());
        assert!(s.get("grad_norm").as_f64().is_some());
        let disp = s.get("displacement").as_arr().expect("per-layer displacement");
        assert_eq!(disp.len(), llama_tiny_cfg().n_layers);
        assert!(s.get("switches").as_arr().is_some());
        assert!(s.get("wall").get("phase_ns").as_obj().is_some());
    }
    let switches: usize =
        steps.iter().map(|s| s.get("switches").as_arr().unwrap().len()).sum();
    assert!(switches > 0, "short-gap Lotus run must record switch events");
}

#[test]
fn sim_trace_validates_and_covers_span_kinds() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let path = tmp_path("trace.json");
    telemetry::install_trace(&path);
    let cfg = sim_cfg(8);
    let mut t = SimTrainer::new(&cfg, lotus_method(), cfg.seed);
    let r = t.train(8);
    assert!(r.final_ppl.is_finite());
    telemetry::finish().expect("write trace");
    let text = std::fs::read_to_string(&path).expect("trace file");
    let _ = std::fs::remove_file(&path);
    let (events, kinds) = telemetry::check_trace(&text).expect("valid Chrome trace");
    assert!(events > 0);
    assert!(kinds >= 6, "expected >= 6 distinct span kinds, got {kinds}");
    for name in ["step", "grad", "update", "project", "opt_step", "lift"] {
        assert!(text.contains(&format!("\"name\":\"{name}\"")), "trace missing {name} spans");
    }
}

#[test]
fn registry_survives_concurrent_get_or_register() {
    // 8 writers (the CI LOTUS_THREADS=8 shape) hammering the same and
    // distinct names; totals must come out exact.
    let c = telemetry::REGISTRY.counter("test.concurrent.hits");
    std::thread::scope(|s| {
        for w in 0..8 {
            let c = telemetry::REGISTRY.counter("test.concurrent.hits");
            let h = telemetry::REGISTRY.histogram("test.concurrent.lat");
            s.spawn(move || {
                for i in 0..1000u64 {
                    c.inc();
                    h.record(w * 1000 + i);
                }
                telemetry::REGISTRY.gauge(&format!("test.concurrent.g{w}")).set(w);
            });
        }
    });
    assert_eq!(c.get(), 8000);
    let h = telemetry::REGISTRY.histogram("test.concurrent.lat");
    assert_eq!(h.count(), 8000);
    for w in 0..8 {
        assert_eq!(telemetry::REGISTRY.gauge(&format!("test.concurrent.g{w}")).get(), w);
    }
}

#[test]
fn histogram_buckets_partition_the_u64_line() {
    let h = telemetry::Histogram::new();
    for v in [0u64, 1, 2, 3, 4, 1023, 1024, u64::MAX] {
        h.record(v);
    }
    assert_eq!(h.count(), 8);
    assert_eq!(h.bucket(0), 1, "zero gets its own bucket");
    assert_eq!(h.bucket(1), 1, "[1,1]");
    assert_eq!(h.bucket(2), 2, "[2,3]");
    assert_eq!(h.bucket(3), 1, "[4,7]");
    assert_eq!(h.bucket(10), 1, "[512,1023]");
    assert_eq!(h.bucket(11), 1, "[1024,2047]");
    assert_eq!(h.bucket(64), 1, "top bucket holds u64::MAX");
}

#[test]
fn report_digest_renders_golden_tables() {
    let stream = concat!(
        "{\"type\":\"step\",\"step\":1,\"loss\":4.0,\"switches\":[],",
        "\"wall\":{\"phase_ns\":{\"grad\":3000000,\"update\":1000000}}}\n",
        "{\"type\":\"step\",\"step\":2,\"loss\":3.5,\"switches\":[{\"layer\":0,",
        "\"mat\":\"wq\",\"reason\":\"displacement\",\"lifetime\":10,\"rank\":16}],",
        "\"wall\":{\"phase_ns\":{\"grad\":3000000,\"update\":1000000}}}\n",
        "{\"type\":\"log\",\"level\":\"INFO\",\"msg\":\"free text, excluded\"}\n",
        "{\"type\":\"step\",\"step\":3,\"loss\":3.0,\"switches\":[],",
        "\"wall\":{\"phase_ns\":{\"grad\":3000000,\"update\":1000000}}}\n",
    );
    let d = telemetry::digest_metrics(stream).expect("digest");
    assert_eq!(d.records, 4);
    assert_eq!(d.steps, 3);
    assert_eq!(d.switches, 1);
    assert_eq!(d.last_loss, Some(3.0));
    let golden_phases = "phase   total_ms  share\n\
                         -----------------------\n\
                         grad    9.000     75.0%\n\
                         update  3.000     25.0%\n";
    assert_eq!(d.phase_table, golden_phases);
    let golden_switches = "reason        switches  mean_lifetime  mean_rank\n\
                           ------------------------------------------------\n\
                           displacement  1         10.0           16.0\n";
    assert_eq!(d.switch_table, golden_switches);
    // same input, same bytes — the report is safe to diff in CI
    assert_eq!(
        telemetry::digest_metrics(stream).unwrap().phase_table,
        d.phase_table
    );
}
