//! Property-based tests over the coordinator invariants, via the
//! in-crate mini framework (`lotus::proptest`).

use lotus::linalg::{matmul, norms, qr, rsvd, svd};
use lotus::optim::{Hyper, LowRankAdam, Optimizer};
use lotus::projection::{side_for, Projector, RandSvdProjector, Side, SvdProjector};
use lotus::proptest::{check, gens, PropResult};
use lotus::subspace::{Decision, LotusAdaSS, Observation, PathEfficiency, SwitchPolicy};
use lotus::tensor::Matrix;
use lotus::util::Rng;

const CASES: usize = 24;

#[test]
fn prop_projector_bases_are_orthonormal() {
    check(
        "projector-orthonormal",
        CASES,
        |rng: &mut Rng| {
            let m = rng.range(4, 48);
            let n = rng.range(4, 48);
            let r = rng.range(1, m.min(n) + 1);
            (Matrix::randn(m, n, 1.0, rng), r, rng.next_u64())
        },
        |(g, r, seed)| -> PropResult {
            for p in [
                SvdProjector.fit(g, *r),
                RandSvdProjector::new(*seed).fit(g, *r),
            ] {
                let err = norms::orthonormality_error(&p.basis);
                if err > 1e-3 {
                    return Err(format!("orthonormality err {err} at rank {r}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_side_rule_minimizes_state() {
    check(
        "side-rule",
        CASES,
        gens::dims(1, 200),
        |&(m, n)| -> PropResult {
            let side = side_for(m, n);
            // retained low-rank state is r×long; basis is short×r. The
            // chosen side must put the basis on the shorter dimension.
            let ok = match side {
                Side::Left => m <= n,
                Side::Right => m > n,
            };
            if ok {
                Ok(())
            } else {
                Err(format!("side {side:?} for {m}x{n}"))
            }
        },
    );
}

#[test]
fn prop_down_up_projection_is_idempotent() {
    check(
        "projection-idempotent",
        CASES,
        |rng: &mut Rng| {
            let m = rng.range(4, 40);
            let n = rng.range(4, 40);
            let r = rng.range(1, m.min(n) + 1);
            (Matrix::randn(m, n, 1.0, rng), r, rng.next_u64())
        },
        |(g, r, seed)| -> PropResult {
            let p = RandSvdProjector::new(*seed).fit(g, *r);
            let low = p.down(g);
            let again = p.down(&p.up(&low));
            let err = again.sub(&low).fro_norm() / low.fro_norm().max(1e-12);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("idempotency err {err}"))
            }
        },
    );
}

#[test]
fn prop_rho_is_bounded() {
    // ρ_t ∈ [0, 1] for any gradient stream (Eq. 3).
    check(
        "rho-bounds",
        CASES,
        |rng: &mut Rng| {
            let steps = rng.range(8, 40);
            let mats: Vec<Matrix> =
                (0..steps).map(|_| Matrix::randn(4, 12, 1.0, rng)).collect();
            mats
        },
        |mats| -> PropResult {
            let mut policy = PathEfficiency::new(4, 0.0, u64::MAX); // never switch
            policy.reset(&mats[0], 0);
            for (i, g) in mats[1..].iter().enumerate() {
                let _ = policy.observe(&Observation { low_grad: g, step: i as u64 + 1 });
                if let Some(rho) = policy.diagnostic() {
                    if !(0.0..=1.0 + 1e-6).contains(&rho) {
                        return Err(format!("rho {rho} out of bounds"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adass_scale_invariance() {
    // Algorithm 1's decisions are invariant to gradient magnitude.
    check(
        "adass-scale-invariant",
        CASES,
        |rng: &mut Rng| {
            let mats: Vec<Matrix> = (0..30).map(|_| Matrix::randn(3, 9, 1.0, rng)).collect();
            let scale = 10f32.powi(rng.range(0, 7) as i32 - 3); // 1e-3 .. 1e3
            (mats, scale)
        },
        |(mats, scale)| -> PropResult {
            let decisions = |s: f32| -> Vec<bool> {
                let mut p = LotusAdaSS::new(0.05, 5, 0);
                let mut first = mats[0].clone();
                first.scale(s);
                p.reset(&first, 0);
                mats[1..]
                    .iter()
                    .enumerate()
                    .map(|(i, g)| {
                        let mut gs = g.clone();
                        gs.scale(s);
                        matches!(
                            p.observe(&Observation { low_grad: &gs, step: i as u64 + 1 }),
                            Decision::Switch(_)
                        )
                    })
                    .collect()
            };
            if decisions(1.0) == decisions(*scale) {
                Ok(())
            } else {
                Err(format!("decisions differ at scale {scale}"))
            }
        },
    );
}

#[test]
fn prop_policy_respects_t_min() {
    check(
        "t-min-respected",
        CASES,
        |rng: &mut Rng| {
            let t_min = rng.range(5, 50) as u64;
            let mats: Vec<Matrix> = (0..60).map(|_| Matrix::randn(3, 6, 0.001, rng)).collect();
            (mats, t_min)
        },
        |(mats, t_min)| -> PropResult {
            // constant-direction grads (stalled) with absurd γ: any η
            // check would switch, so the first switch time is governed
            // purely by t_min.
            let mut p = LotusAdaSS::new(10.0, 2, *t_min);
            p.reset(&mats[0], 0);
            for (i, g) in mats[1..].iter().enumerate() {
                let step = i as u64 + 1;
                if let Decision::Switch(_) = p.observe(&Observation { low_grad: g, step }) {
                    if step < *t_min {
                        return Err(format!("switched at {step} < t_min {t_min}"));
                    }
                    return Ok(());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lowrank_update_stays_in_span() {
    check(
        "update-in-span",
        CASES,
        |rng: &mut Rng| {
            let m = rng.range(4, 32);
            let n = rng.range(4, 32);
            let r = rng.range(1, m.min(n) + 1);
            (m, n, r, rng.next_u64())
        },
        |&(m, n, r, seed)| -> PropResult {
            let mut rng = Rng::new(seed);
            let mut opt = LowRankAdam::new(
                r,
                Box::new(RandSvdProjector::new(seed)),
                Box::new(lotus::subspace::FixedInterval::new(1_000_000)),
            );
            let w0 = Matrix::randn(m, n, 1.0, &mut rng);
            let mut w = w0.clone();
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            opt.step(&mut w, &g, &Hyper { weight_decay: 0.0, ..Default::default() }, 1);
            let dw = w.sub(&w0);
            let p = opt.projection().unwrap();
            let err = p.up(&p.down(&dw)).sub(&dw).fro_norm() / dw.fro_norm().max(1e-12);
            if err < 5e-3 {
                Ok(())
            } else {
                Err(format!("ΔW outside span: {err}"))
            }
        },
    );
}

#[test]
fn prop_qr_reconstructs() {
    check(
        "qr-reconstruction",
        CASES,
        |rng: &mut Rng| {
            let m = rng.range(2, 60);
            let n = rng.range(1, m + 1); // tall
            Matrix::randn(m, n, 1.0, rng)
        },
        |a| -> PropResult {
            let f = qr::qr_thin(a);
            let rec = matmul(&f.q, &f.r);
            let err = rec.sub(a).fro_norm() / a.fro_norm().max(1e-12);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("qr err {err}"))
            }
        },
    );
}

#[test]
fn prop_svd_reconstructs_and_is_sorted() {
    check(
        "svd-reconstruction",
        16,
        gens::matrix(2, 28, 1.0),
        |a| -> PropResult {
            let s = svd::svd_jacobi(a);
            for w in s.s.windows(2) {
                if w[0] < w[1] - 1e-5 {
                    return Err(format!("unsorted spectrum {:?}", &s.s));
                }
            }
            let rec = s.reconstruct(a.rows.min(a.cols));
            let err = rec.sub(a).fro_norm() / a.fro_norm().max(1e-12);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("svd err {err}"))
            }
        },
    );
}

#[test]
fn prop_rsvd_energy_close_to_svd() {
    check(
        "rsvd-vs-svd-energy",
        12,
        |rng: &mut Rng| {
            // decaying-spectrum matrix: D^k scaled gaussian. Keep m <= n
            // (Left side) so captured_energy's basis orientation applies.
            let m = rng.range(16, 48);
            let n = rng.range(m, 48.max(m + 1));
            let mut a = Matrix::randn(m, n, 1.0, rng);
            // impose decay by scaling rows
            for i in 0..m {
                let f = 1.0 / (1.0 + i as f32);
                for v in a.row_mut(i) {
                    *v *= f;
                }
            }
            (a, rng.next_u64())
        },
        |(a, seed)| -> PropResult {
            let r = 6.min(a.rows.min(a.cols));
            let p_svd = SvdProjector.fit(a, r);
            let p_rsvd = RandSvdProjector::with_opts(*seed, 8, 2).fit(a, r);
            let e_svd = norms::captured_energy(&p_svd.basis, a);
            let e_rsvd = norms::captured_energy(&p_rsvd.basis, a);
            if e_rsvd >= e_svd * 0.9 - 1e-9 {
                Ok(())
            } else {
                Err(format!("rsvd {e_rsvd} vs svd {e_svd}"))
            }
        },
    );
}

#[test]
fn prop_rsvd_flop_model_monotone() {
    check(
        "rsvd-flops-monotone",
        CASES,
        |rng: &mut Rng| (rng.range(64, 2048), rng.range(64, 2048), rng.range(4, 64)),
        |&(m, n, r)| -> PropResult {
            let f1 = rsvd::rsvd_flops(m, n, r, 4, 1);
            let f2 = rsvd::rsvd_flops(m, n, r * 2, 4, 1);
            let f3 = rsvd::rsvd_flops(m * 2, n, r, 4, 1);
            if f2 > f1 && f3 > f1 {
                Ok(())
            } else {
                Err(format!("non-monotone: {f1} {f2} {f3}"))
            }
        },
    );
}

#[test]
fn prop_config_roundtrip() {
    use lotus::config::RunConfig;
    use lotus::sim::trainer::Method;
    check(
        "config-roundtrip",
        CASES,
        |rng: &mut Rng| {
            let mut cfg = RunConfig::default();
            cfg.steps = rng.range(1, 10_000) as u64;
            cfg.batch = rng.range(1, 64);
            cfg.seed = rng.next_u64() % 100_000;
            cfg.method.rank = rng.range(1, 65);
            cfg.method.method = match rng.range(0, 5) {
                0 => Method::FullRank,
                1 => Method::GaLore { interval: rng.range(1, 500) as u64 },
                2 => Method::Lotus {
                    gamma: 0.005 + rng.f64() * 0.5,
                    eta: rng.range(1, 100) as u64,
                    t_min: rng.range(0, 100) as u64,
                },
                3 => Method::Apollo { refresh_every: rng.range(1, 500) as u64 },
                _ => Method::LoRA,
            };
            cfg
        },
        |cfg| -> PropResult {
            let text = cfg.to_toml();
            let back = RunConfig::from_toml(&text).map_err(|e| e)?;
            if back.steps == cfg.steps
                && back.batch == cfg.batch
                && back.seed == cfg.seed
                && back.method == cfg.method
            {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        },
    );
}

#[test]
fn prop_checkpoint_roundtrip_arbitrary_tensors() {
    use lotus::train::checkpoint;
    check(
        "checkpoint-roundtrip",
        12,
        |rng: &mut Rng| {
            let n = rng.range(1, 6);
            (0..n)
                .map(|i| {
                    let r = rng.range(1, 20);
                    let c = rng.range(1, 20);
                    (format!("t{i}"), Matrix::randn(r, c, 1.0, rng))
                })
                .collect::<Vec<_>>()
        },
        |tensors| -> PropResult {
            let cfg = lotus::models::presets::llama_tiny_cfg();
            let params = lotus::train::HostParams::init(cfg, 5);
            let path = std::env::temp_dir().join(format!(
                "lotus_prop_ckpt_{}.ckpt",
                std::process::id()
            ));
            let extra: Vec<(String, &Matrix)> =
                tensors.iter().map(|(n, m)| (n.clone(), m)).collect();
            checkpoint::save(&path, 9, &params, &extra).map_err(|e| e.to_string())?;
            let (step, loaded) = checkpoint::load(&path).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&path);
            if step != 9 {
                return Err("step lost".into());
            }
            for (name, m) in tensors {
                let found = loaded.iter().find(|(n, _)| n == name);
                match found {
                    Some((_, lm)) if lm == m => {}
                    _ => return Err(format!("tensor {name} not restored exactly")),
                }
            }
            Ok(())
        },
    );
}
