//! Integration coverage for the parallel, allocation-free linalg engine:
//! `*_into` kernels vs the allocating originals, pooled kernels across
//! thread counts, workspace reuse under sustained load, and the fused
//! low-rank optimizer step against a step-by-step reference.

use lotus::linalg::matmul::{
    matmul, matmul_into, matmul_nt, matmul_nt_into, matmul_tn, matmul_tn_into,
};
use lotus::linalg::par::{matmul_nt_pooled, matmul_pooled, matmul_tn_pooled};
use lotus::linalg::rsvd::{rsvd_range, rsvd_range_into, RsvdOpts, RsvdScratch};
use lotus::optim::adam::bias_correction;
use lotus::optim::lowrank::presets;
use lotus::optim::{Hyper, Optimizer};
use lotus::runtime::pool::Pool;
use lotus::tensor::Matrix;
use lotus::util::Rng;

/// The seeded shapes the crate's kernel tests sweep; the last one sits
/// above the pooled kernels' small-shape cutoff so real row-band
/// parallelism is exercised.
const SHAPES: [(usize, usize, usize); 6] =
    [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 65, 70), (100, 1, 100), (130, 110, 90)];

#[test]
fn into_variants_match_allocating_kernels_bit_for_bit() {
    let mut rng = Rng::new(201);
    for &(m, k, n) in &SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut c = Matrix::zeros(m, n);
        matmul_into(&a, &b, &mut c);
        assert_eq!(c.data, matmul(&a, &b).data, "nn ({m},{k},{n})");

        let bt = b.transpose();
        let mut cnt = Matrix::zeros(m, n);
        matmul_nt_into(&a, &bt, &mut cnt);
        assert_eq!(cnt.data, matmul_nt(&a, &bt).data, "nt ({m},{k},{n})");

        let at = Matrix::randn(k, m, 1.0, &mut rng);
        let b2 = Matrix::randn(k, n, 1.0, &mut rng);
        let mut ctn = Matrix::zeros(m, n);
        matmul_tn_into(&at, &b2, &mut ctn);
        assert_eq!(ctn.data, matmul_tn(&at, &b2).data, "tn ({m},{k},{n})");
    }
}

#[test]
fn pooled_kernels_identical_for_1_2_and_8_threads() {
    let mut rng = Rng::new(202);
    for &(m, k, n) in &SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let at = Matrix::randn(k, m, 1.0, &mut rng);
        let nn = matmul(&a, &b);
        let nt = matmul_nt(&a, &bt);
        let tn = matmul_tn(&at, &b);
        for threads in [1usize, 2, 8] {
            let pool = Pool::with_threads(threads);
            assert_eq!(matmul_pooled(&pool, &a, &b).data, nn.data, "nn t={threads}");
            assert_eq!(matmul_nt_pooled(&pool, &a, &bt).data, nt.data, "nt t={threads}");
            assert_eq!(matmul_tn_pooled(&pool, &at, &b).data, tn.data, "tn t={threads}");
        }
    }
}

#[test]
fn rsvd_range_identical_for_1_2_and_8_threads() {
    let mut rng = Rng::new(203);
    // big enough that the range finder's GEMMs take the banded path
    let a = Matrix::randn(256, 160, 1.0, &mut rng);
    let opts = RsvdOpts { rank: 48, oversample: 4, power_iters: 2 };
    let mut rng_ref = Rng::new(204);
    let reference = rsvd_range(&a, opts, &mut rng_ref);
    for threads in [1usize, 2, 8] {
        let pool = Pool::with_threads(threads);
        let mut scratch = RsvdScratch::new();
        let mut out = Matrix::zeros(0, 0);
        let mut rng_t = Rng::new(204);
        rsvd_range_into(&a, opts, &mut rng_t, &pool, &mut scratch, &mut out);
        assert_eq!(out.data, reference.data, "threads={threads}");
    }
}

#[test]
fn workspace_reuse_across_100_iterations_never_changes_results() {
    // Drive the rSVD scratch (the optimizer's refresh path) through 100
    // refreshes over alternating shapes; every result must match the
    // allocating implementation fed the same RNG stream. A stale-scratch
    // bug (a buffer not fully overwritten between borrowers) breaks the
    // bit equality immediately.
    let mut rng = Rng::new(205);
    let shapes = [(64usize, 48usize), (48, 64), (32, 32)];
    let mats: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 1.0, &mut rng)).collect();
    let opts = RsvdOpts { rank: 8, oversample: 4, power_iters: 1 };
    let pool = Pool::with_threads(2);
    let mut scratch = RsvdScratch::new();
    let mut out = Matrix::zeros(0, 0);
    let mut rng_into = Rng::new(206);
    let mut rng_ref = Rng::new(206);
    for it in 0..100 {
        let a = &mats[it % mats.len()];
        rsvd_range_into(a, opts, &mut rng_into, &pool, &mut scratch, &mut out);
        let reference = rsvd_range(a, opts, &mut rng_ref);
        assert_eq!(out.data, reference.data, "iteration {it}");
    }
}

#[test]
fn fused_lowrank_step_matches_manual_reference() {
    // One GaLore step (exact-SVD projector: deterministic, no RNG) checked
    // against the textbook sequence: low = down(g); Adam moments; dir;
    // w -= scale * up(dir). The fused path folds the lift into the weight
    // update, so allow rounding-level tolerance.
    let mut rng = Rng::new(207);
    for (m, n) in [(24, 56), (56, 24)] {
        let w0 = Matrix::randn(m, n, 1.0, &mut rng);
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let hyper = Hyper { lr: 0.01, galore_scale: 0.5, weight_decay: 0.0, ..Default::default() };

        let mut opt = presets::galore(6, 1_000_000);
        let mut w = w0.clone();
        opt.step(&mut w, &g, &hyper, 1);

        // reference from the fitted projection
        let p = opt.projection().unwrap().clone();
        let low = p.down(&g);
        let (c1, c2) = bias_correction(hyper.beta1, hyper.beta2, 1);
        let mut dir = Matrix::zeros(low.rows, low.cols);
        for i in 0..low.data.len() {
            let gi = low.data[i];
            let mi = (1.0 - hyper.beta1) * gi;
            let vi = (1.0 - hyper.beta2) * gi * gi;
            let mhat = mi as f64 / c1;
            let vhat = (vi as f64 / c2).sqrt() + hyper.eps as f64;
            dir.data[i] = (hyper.lr as f64 * mhat / vhat) as f32;
        }
        let mut w_ref = w0.clone();
        w_ref.axpy(-hyper.galore_scale, &p.up(&dir));

        let err = w.sub(&w_ref).fro_norm() / w_ref.fro_norm().max(1.0);
        assert!(err < 1e-5, "({m},{n}) fused step drifted: {err}");
    }
}

#[test]
fn fused_lowrank_trajectory_stable_over_100_steps() {
    // 100 steps with persistent scratch must stay glued to an
    // independently constructed optimizer fed identical inputs
    // (determinism) and keep reducing the quadratic (sanity).
    let mut rng = Rng::new(208);
    let target = Matrix::randn(16, 40, 1.0, &mut rng);
    let hyper = Hyper { lr: 0.05, galore_scale: 1.0, ..Default::default() };

    let mut opt_a = presets::galore(8, 25);
    let mut opt_b = presets::galore(8, 25);
    let mut wa = Matrix::zeros(16, 40);
    let mut wb = Matrix::zeros(16, 40);
    let rel0 = wa.sub(&target).fro_norm() / target.fro_norm();
    for t in 1..=100 {
        let ga = wa.sub(&target);
        let gb = wb.sub(&target);
        opt_a.step(&mut wa, &ga, &hyper, t);
        opt_b.step(&mut wb, &gb, &hyper, t);
        assert_eq!(wa.data, wb.data, "trajectories diverged at step {t}");
    }
    let rel = wa.sub(&target).fro_norm() / target.fro_norm();
    assert!(rel < rel0, "no progress: {rel0} -> {rel}");
}
