//! Method-matrix tests for the unified `Optimizer` trait (ISSUE 3
//! acceptance): every registered method, built through the single
//! registry, must (a) round-trip step → export_state → (tensor codec) →
//! restore_state → step with a bit-identical trajectory *and* identical
//! events, and (b) report measured `state_bytes()` agreeing with
//! `memcount`'s analytic model.

use lotus::memcount;
use lotus::optim::registry::{self, TrainPhase};
use lotus::optim::{Hyper, Method, OptState, Optimizer};
use lotus::tensor::Matrix;
use lotus::util::Rng;

/// Every registered method, at test-scale hyper-parameters (switchy
/// intervals so the round-trip window crosses subspace switches,
/// adapter merges and rank decays).
fn methods() -> Vec<Method> {
    vec![
        Method::FullRank,
        Method::GaLore { interval: 4 },
        Method::LowRank,
        Method::LoRA,
        Method::ReLoRA { merge_every: 4 },
        Method::AdaRankGrad { interval: 4, decay: 0.5 },
        Method::Apollo { refresh_every: 4 },
        Method::Lotus { gamma: 0.9, eta: 3, t_min: 2 },
        Method::RsvdFixed { interval: 4 },
    ]
}

#[test]
fn every_method_roundtrips_through_export_restore_bit_identically() {
    let hyper = Hyper { lr: 2e-3, galore_scale: 0.5, ..Default::default() };
    for method in methods() {
        // both side-rule branches (Left: m<=n, Right: m>n)
        for (m, n) in [(12usize, 28usize), (28, 12)] {
            let mut data_rng = Rng::new(501);
            let grads: Vec<Matrix> =
                (0..16).map(|_| Matrix::randn(m, n, 1.0, &mut data_rng)).collect();

            let mut ctor_a = Rng::new(7);
            let mut a = registry::build(method, 4, m, n, 11, &mut ctor_a, TrainPhase::Pretrain);
            let mut wa = Matrix::randn(m, n, 0.3, &mut Rng::new(33));
            for (i, g) in grads[..8].iter().enumerate() {
                let _ = a.step(&mut wa, g, &hyper, i as u64 + 1);
            }

            // a freshly built optimizer of the same spec, with the
            // exported state pushed through the tensor codec, must
            // continue bit-for-bit — weights AND events
            let mut ctor_b = Rng::new(7);
            let mut b = registry::build(method, 4, m, n, 11, &mut ctor_b, TrainPhase::Pretrain);
            let mut tensors = Vec::new();
            a.export_state().to_tensors("opt/m0", &mut tensors);
            let back = OptState::from_tensors("opt/m0", &tensors).unwrap();
            b.restore_state(back).unwrap();

            let mut wb = wa.clone();
            for (i, g) in grads[8..].iter().enumerate() {
                let t = i as u64 + 9;
                let ea = a.step(&mut wa, g, &hyper, t);
                let eb = b.step(&mut wb, g, &hyper, t);
                assert_eq!(ea, eb, "{} ({m}x{n}): event diverged at step {t}", method.name());
                assert_eq!(
                    wa.data,
                    wb.data,
                    "{} ({m}x{n}): weights diverged at step {t}",
                    method.name()
                );
            }
        }
    }
}

#[test]
fn prefit_snapshot_rolls_back_a_stepped_optimizer_exactly() {
    // Restoring is a rollback: a snapshot taken BEFORE the first fit
    // (OptState::Empty for the projection methods), restored into an
    // optimizer that has since stepped, must rewind it — including the
    // projector RNG stream — so replaying the same gradients matches a
    // freshly built optimizer bit-for-bit.
    let hyper = Hyper { lr: 2e-3, galore_scale: 0.5, ..Default::default() };
    let probed = [
        Method::Lotus { gamma: 0.9, eta: 3, t_min: 2 },
        Method::RsvdFixed { interval: 3 },
        Method::Apollo { refresh_every: 3 },
        Method::AdaRankGrad { interval: 3, decay: 0.5 },
    ];
    for method in probed {
        let mut ctor = Rng::new(21);
        let mut opt = registry::build(method, 4, 10, 18, 13, &mut ctor, TrainPhase::Pretrain);
        let prefit = opt.export_state();
        let mut data_rng = Rng::new(601);
        let grads: Vec<Matrix> =
            (0..6).map(|_| Matrix::randn(10, 18, 1.0, &mut data_rng)).collect();
        let w0 = Matrix::randn(10, 18, 0.3, &mut Rng::new(22));
        let mut wa = w0.clone();
        for (i, g) in grads.iter().enumerate() {
            let _ = opt.step(&mut wa, g, &hyper, i as u64 + 1);
        }
        opt.restore_state(prefit).unwrap();
        let mut ctor2 = Rng::new(21);
        let mut fresh = registry::build(method, 4, 10, 18, 13, &mut ctor2, TrainPhase::Pretrain);
        let mut wb = w0.clone();
        let mut wc = w0.clone();
        for (i, g) in grads.iter().enumerate() {
            let t = i as u64 + 1;
            assert_eq!(
                opt.step(&mut wb, g, &hyper, t),
                fresh.step(&mut wc, g, &hyper, t),
                "{}: event diverged after rollback at step {t}",
                method.name()
            );
            assert_eq!(
                wb.data,
                wc.data,
                "{}: rollback replay diverged at step {t}",
                method.name()
            );
        }
    }
}

#[test]
fn restore_rejects_a_snapshot_from_a_different_method() {
    let mut rng = Rng::new(1);
    let mut adam = registry::build(Method::FullRank, 4, 8, 8, 1, &mut rng, TrainPhase::Pretrain);
    let mut lora = registry::build(Method::LoRA, 4, 8, 8, 1, &mut rng, TrainPhase::Pretrain);
    // give LoRA real state so it exports its own variant
    let hyper = Hyper::default();
    let mut w = Matrix::zeros(8, 8);
    let g = Matrix::randn(8, 8, 1.0, &mut Rng::new(2));
    let _ = lora.step(&mut w, &g, &hyper, 1);
    let err = adam.restore_state(lora.export_state());
    assert!(err.is_err(), "adam must reject a lora snapshot");
}

#[test]
fn measured_state_bytes_match_the_analytic_model() {
    // One warm step, then measured state_bytes must equal memcount's
    // analytic opt_state. AdaRankGrad's analytic row models the decayed
    // *average* rank (0.75r), so it is bounded by the fixed-rank GaLore
    // figure at the starting rank instead of checked exactly.
    let hyper = Hyper::default();
    let (m, n, r) = (24usize, 56usize, 4usize);
    for method in methods() {
        let mut rng = Rng::new(3);
        let mut opt = registry::build(method, r, m, n, 5, &mut rng, TrainPhase::Pretrain);
        let mut w = Matrix::randn(m, n, 0.1, &mut Rng::new(8));
        let g = Matrix::randn(m, n, 1.0, &mut Rng::new(9));
        let _ = opt.step(&mut w, &g, &hyper, 1);
        let measured = opt.state_bytes() as u64;
        match method {
            Method::AdaRankGrad { .. } => {
                let bound =
                    memcount::layer_mem(memcount::Method::GaLore, m as u64, n as u64, r as u64, 4)
                        .opt_state;
                assert!(
                    measured <= bound,
                    "{}: measured {measured} above fixed-rank bound {bound}",
                    method.name()
                );
            }
            _ => {
                let analytic =
                    memcount::layer_mem(method.memcount(), m as u64, n as u64, r as u64, 4)
                        .opt_state;
                assert_eq!(measured, analytic, "{}", method.name());
            }
        }
    }
}

#[test]
fn sim_trainer_checkpoint_roundtrips_for_every_method() {
    // Trainer-level acceptance: save mid-run, restore into a fresh
    // trainer, continue — weights must match the uninterrupted run
    // bit-for-bit for EVERY registered method (not just LowRankAdam).
    use lotus::models::presets::llama_tiny_cfg;
    use lotus::sim::trainer::{SimRunCfg, SimTrainer};

    let dir = std::env::temp_dir().join("lotus_sim_ckpt_matrix");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 8, 7);
    cfg.batch = 2;
    cfg.eval_every = 1_000_000; // final eval only
    cfg.eval_batches = 1;

    for method in methods() {
        let path = dir.join(format!("{}.ckpt", method.name().replace([' ', '+'], "_")));
        let mut a = SimTrainer::new(&cfg, method, 5);
        let _ = a.train(4);
        a.save_checkpoint(&path).unwrap();
        let cont = a.train(3);

        let mut b = SimTrainer::new(&cfg, method, 5);
        let step = b.load_checkpoint(&path).unwrap();
        assert_eq!(step, 4, "{}: resume step", method.name());
        let resumed = b.train(3);
        assert_eq!(
            resumed.final_ppl,
            cont.final_ppl,
            "{}: ppl after resume",
            method.name()
        );
        let (pa, pb) = (&a.model().params, &b.model().params);
        assert_eq!(pa.embed.data, pb.embed.data, "{}: embed", method.name());
        assert_eq!(pa.final_norm, pb.final_norm, "{}: final_norm", method.name());
        for (i, (la, lb)) in pa.layers.iter().zip(&pb.layers).enumerate() {
            assert_eq!(la.wq.data, lb.wq.data, "{}: L{i}/wq", method.name());
            assert_eq!(la.w2.data, lb.w2.data, "{}: L{i}/w2", method.name());
            assert_eq!(la.norm1, lb.norm1, "{}: L{i}/norm1", method.name());
        }
        let _ = std::fs::remove_file(&path);
    }
}
