//! Recovery determinism under injected faults (PR 6 acceptance).
//!
//! The contract: every recovery path lands the run on a trajectory that
//! is **bit-identical** to an oracle that never saw the fault —
//! corruption/drop/duplicate/delay are absorbed by the checksummed
//! retrying comm layer (same weights, same payload byte accounting);
//! a killed worker re-shards onto the survivors exactly like a fresh
//! N−1 run resumed from that step; NaN gradients and loss spikes roll
//! back to the last periodic checkpoint and replay byte-exact. The CI
//! fault matrix re-runs this file under `LOTUS_THREADS=1` and `=4`.

use lotus::dist::{DistCfg, DistTrainer};
use lotus::faults::{FaultPlan, GuardCfg};
use lotus::models::presets::llama_tiny_cfg;
use lotus::serve::{Sampling, ServeEngine};
use lotus::sim::model::{Params, SimModel};
use lotus::sim::trainer::{Method, SimRunCfg};

fn quick_cfg(steps: u64) -> SimRunCfg {
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;
    cfg.eval_every = 1_000_000; // no mid-run evals; final eval only
    cfg.eval_batches = 2;
    cfg
}

fn lotus_switchy() -> Method {
    // aggressive thresholds so consensus switches fire within short runs
    Method::Lotus { gamma: 0.9, eta: 3, t_min: 2 }
}

fn dist(workers: usize, shards: usize) -> DistCfg {
    DistCfg { workers, shards, quorum: 0.5 }
}

fn assert_params_identical(a: &Params, b: &Params, tag: &str) {
    assert_eq!(a.embed.data, b.embed.data, "{tag}: embed");
    assert_eq!(a.final_norm, b.final_norm, "{tag}: final_norm");
    assert_eq!(a.layers.len(), b.layers.len(), "{tag}: layer count");
    for (i, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.wq.data, lb.wq.data, "{tag}: L{i}/wq");
        assert_eq!(la.wk.data, lb.wk.data, "{tag}: L{i}/wk");
        assert_eq!(la.wv.data, lb.wv.data, "{tag}: L{i}/wv");
        assert_eq!(la.wo.data, lb.wo.data, "{tag}: L{i}/wo");
        assert_eq!(la.w1.data, lb.w1.data, "{tag}: L{i}/w1");
        assert_eq!(la.w3.data, lb.w3.data, "{tag}: L{i}/w3");
        assert_eq!(la.w2.data, lb.w2.data, "{tag}: L{i}/w2");
        assert_eq!(la.norm1, lb.norm1, "{tag}: L{i}/norm1");
        assert_eq!(la.norm2, lb.norm2, "{tag}: L{i}/norm2");
    }
}

#[test]
fn corruption_retry_run_matches_fault_free_run() {
    // One bit flip, one drop, one duplicate and one delay across four
    // steps: the hardened comm layer detects and retries, the recovered
    // run lands on bit-identical weights and losses, and the payload
    // byte accounting matches the fault-free run exactly — only the
    // fault/retry counters differ.
    let cfg = quick_cfg(10);
    let mut clean = DistTrainer::new(&cfg, lotus_switchy(), dist(2, 4), 31).unwrap();
    let clean_report = clean.train(10);

    let mut faulty = DistTrainer::new(&cfg, lotus_switchy(), dist(2, 4), 31).unwrap();
    faulty.arm_faults(FaultPlan::parse("flip@2,drop@3,dup@4,delay@5", 9).unwrap());
    let faulty_report = faulty.train_checkpointed(10, 0, "", "x").unwrap();

    assert_params_identical(&clean.model().params, &faulty.model().params, "retry vs clean");
    assert_eq!(faulty_report.losses, clean_report.losses, "loss curve diverged");
    assert_eq!(faulty_report.final_ppl, clean_report.final_ppl, "final ppl diverged");

    // every scheduled payload fault actually fired ...
    assert_eq!(faulty_report.faults.bit_flips, 1);
    assert_eq!(faulty_report.faults.drops, 1);
    assert_eq!(faulty_report.faults.duplicates, 1);
    assert_eq!(faulty_report.faults.delays, 1);
    // ... was detected and accounted ...
    assert_eq!(faulty_report.comm.checksum_failures, 1, "flip not caught");
    assert_eq!(faulty_report.comm.dropped_payloads, 1, "drop not caught");
    assert_eq!(faulty_report.comm.duplicate_payloads, 1, "dup not deduplicated");
    assert_eq!(faulty_report.comm.delayed_payloads, 1, "delay not seen");
    // ... and only the flip + drop needed a resend (dup/delay do not)
    assert_eq!(faulty_report.comm.retries, 2, "{:?}", faulty_report.comm);
    assert!(faulty_report.comm.retry_bytes > 0);
    assert!(faulty_report.comm.backoff_units > 0);

    // payload byte accounting is byte-exact once retry counters are set
    // aside (retry bytes live in their own counter by design)
    assert_eq!(faulty_report.comm.without_fault_counters(), clean_report.comm);
    assert!(clean_report.comm.checksummed_payloads > 0, "steady path must checksum");
    assert_eq!(
        faulty_report.comm.checksummed_payloads, clean_report.comm.checksummed_payloads,
        "retries must not inflate the per-transfer checksum count"
    );
}

#[test]
fn worker_death_matches_fresh_survivor_run_resumed_at_that_step() {
    // Kill worker 0 of 2 at step 7 of 11. The elastic re-shard must be
    // bit-identical to the oracle: a fault-free N=2 run checkpointed at
    // step 6 and resumed by a fresh N=1 trainer for the remaining steps.
    let cfg = quick_cfg(11);
    let method = lotus_switchy();
    let dir = std::env::temp_dir().join("lotus_faults_kill");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("oracle.ckpt");

    let mut a = DistTrainer::new(&cfg, method, dist(2, 4), 7).unwrap();
    let before = a.train(6);
    a.save_checkpoint(&path).unwrap();
    let mut b = DistTrainer::new(&cfg, method, dist(1, 4), 7).unwrap();
    assert_eq!(b.load_checkpoint(&path).unwrap(), 6, "oracle resume step");
    let after = b.train(5); // steps 7..=11 at the survivor world size

    let mut faulty = DistTrainer::new(&cfg, method, dist(2, 4), 7).unwrap();
    faulty.arm_faults(FaultPlan::parse("kill0@7", 9).unwrap());
    let faulty_report = faulty.train_checkpointed(11, 0, "", "x").unwrap();

    assert_eq!(faulty_report.faults.worker_kills, 1);
    assert_eq!(faulty_report.recovery.worker_deaths, 1);
    assert_eq!(faulty.world_size(), 1, "survivor world size");
    assert_eq!(faulty.shard_count(), 4, "the shard decomposition never changes");
    let oracle_losses: Vec<f64> =
        before.losses.iter().chain(&after.losses).copied().collect();
    assert_eq!(faulty_report.losses, oracle_losses, "losses diverged around the death");
    assert_params_identical(&b.model().params, &faulty.model().params, "survivor vs oracle");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn nan_gradient_rolls_back_and_matches_fault_free_run() {
    // A NaN gradient at step 5 with checkpoints every 3 steps: the guard
    // withholds the update, rolls back to the step-3 checkpoint and
    // replays — the fault fires once, so the replay is clean and the
    // final weights match a run that never saw the NaN.
    let cfg = quick_cfg(12);
    let method = lotus_switchy();
    let dir = std::env::temp_dir().join("lotus_faults_nan");

    let mut clean = DistTrainer::new(&cfg, method, dist(2, 4), 13).unwrap();
    let clean_report = clean.train(12);

    let mut faulty = DistTrainer::new(&cfg, method, dist(2, 4), 13).unwrap();
    faulty.arm_faults(FaultPlan::parse("nan@5", 9).unwrap());
    let faulty_report =
        faulty.train_checkpointed(12, 3, dir.to_str().unwrap(), "nan-run").unwrap();

    assert_eq!(faulty_report.faults.nan_grads, 1);
    assert_eq!(faulty_report.recovery.rollbacks, 1, "{:?}", faulty_report.recovery);
    assert_eq!(faulty_report.recovery.skipped_steps, 0, "rollback, not skip");
    assert_eq!(faulty_report.losses, clean_report.losses, "replayed curve diverged");
    assert_eq!(faulty_report.final_ppl, clean_report.final_ppl);
    assert_params_identical(&clean.model().params, &faulty.model().params, "nan vs clean");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn nan_gradient_without_checkpoint_skips_the_step() {
    // Same fault, no checkpointing: the guard falls back to skip-step —
    // the poisoned update is withheld, nothing leaks into the moments,
    // and training continues with one loss sample missing.
    let cfg = quick_cfg(12);
    let mut t = DistTrainer::new(&cfg, lotus_switchy(), dist(2, 4), 13).unwrap();
    t.arm_faults(FaultPlan::parse("nan@5", 9).unwrap());
    let r = t.train_checkpointed(12, 0, "", "x").unwrap();
    assert_eq!(r.recovery.skipped_steps, 1, "{:?}", r.recovery);
    assert_eq!(r.recovery.rollbacks, 0);
    assert_eq!(r.losses.len(), 11, "the skipped step contributes no loss");
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(r.final_ppl.is_finite());
}

#[test]
fn loss_spike_rolls_back_and_matches_fault_free_run() {
    // Silent weight corruption at step 7 (tied embedding × 25 → logits
    // × 25 → the loss explodes): the windowed detector flags the spike,
    // rolls back to the step-6 checkpoint and replays clean.
    let cfg = quick_cfg(12);
    let method = lotus_switchy();
    let guard = GuardCfg { spike_window: 4, spike_factor: 2.5, ..GuardCfg::default() };
    let dir = std::env::temp_dir().join("lotus_faults_spike");

    let mut clean = DistTrainer::new(&cfg, method, dist(2, 4), 17).unwrap();
    clean.set_guards(guard);
    let clean_report = clean.train(12);

    let mut faulty = DistTrainer::new(&cfg, method, dist(2, 4), 17).unwrap();
    faulty.set_guards(guard);
    faulty.arm_faults(FaultPlan::parse("spike@7", 9).unwrap());
    let faulty_report =
        faulty.train_checkpointed(12, 3, dir.to_str().unwrap(), "spike-run").unwrap();

    assert_eq!(faulty_report.faults.weight_corruptions, 1);
    assert_eq!(faulty_report.recovery.loss_spikes, 1, "{:?}", faulty_report.recovery);
    assert_eq!(faulty_report.recovery.rollbacks, 1, "{:?}", faulty_report.recovery);
    assert_eq!(faulty_report.losses, clean_report.losses, "replayed curve diverged");
    assert!(faulty_report.losses.iter().all(|l| l.is_finite()));
    assert_params_identical(&clean.model().params, &faulty.model().params, "spike vs clean");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn quorum_confirmed_spike_rolls_back_every_worker_to_the_agreed_step() {
    // The ×25 weight corruption inflates every shard's local loss, so
    // the per-shard detectors reach quorum (≥ 2 of 4 at quorum 0.5) and
    // all replicas restore the agreed checkpoint in lockstep. Votes are
    // shard-indexed, so the round — and the replayed trajectory — must
    // be bit-identical at every worker count.
    let cfg = quick_cfg(12);
    let method = lotus_switchy();
    let guard = GuardCfg { spike_window: 4, spike_factor: 2.5, ..GuardCfg::default() };
    let dir = std::env::temp_dir().join("lotus_faults_quorum");

    let mut clean = DistTrainer::new(&cfg, method, dist(2, 4), 17).unwrap();
    clean.set_guards(guard);
    let clean_report = clean.train(12);

    for workers in [1usize, 2] {
        let mut faulty = DistTrainer::new(&cfg, method, dist(workers, 4), 17).unwrap();
        faulty.set_guards(guard);
        faulty.arm_faults(FaultPlan::parse("spike@7", 9).unwrap());
        let run_dir = dir.join(format!("w{workers}"));
        let r = faulty
            .train_checkpointed(12, 3, run_dir.to_str().unwrap(), "quorum-run")
            .unwrap();

        assert_eq!(r.rollback.rounds, 1, "w{workers}: {:?}", r.rollback);
        assert_eq!(r.rollback.committed, 1, "w{workers}: quorum must commit the restore");
        assert_eq!(r.rollback.outvoted, 0, "w{workers}");
        assert!(
            r.rollback.proposals >= 2,
            "w{workers}: a committed round needs ≥ 2 of 4 shard votes, got {:?}",
            r.rollback
        );
        assert_eq!(r.recovery.rollbacks, 1, "w{workers}: {:?}", r.recovery);
        assert_eq!(r.losses, clean_report.losses, "w{workers}: replayed curve diverged");
        assert_params_identical(
            &clean.model().params,
            &faulty.model().params,
            &format!("quorum w{workers} vs clean"),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn minority_false_vote_is_outvoted_and_perturbs_nothing() {
    // Shard 1 casts a forced restore proposal at step 9 while the other
    // three shards see a healthy trajectory: 1 of 4 votes misses the
    // quorum of 2, the round is recorded as outvoted, no checkpoint is
    // restored, and the run stays bit-identical to a fault-free one.
    let cfg = quick_cfg(12);
    let method = lotus_switchy();
    let dir = std::env::temp_dir().join("lotus_faults_outvote");

    let mut clean = DistTrainer::new(&cfg, method, dist(2, 4), 13).unwrap();
    let clean_report = clean.train(12);

    let mut faulty = DistTrainer::new(&cfg, method, dist(2, 4), 13).unwrap();
    faulty.arm_faults(FaultPlan::parse("vote1@9", 9).unwrap());
    let r = faulty.train_checkpointed(12, 3, dir.to_str().unwrap(), "outvote-run").unwrap();

    assert_eq!(r.faults.false_votes, 1, "{:?}", r.faults);
    assert_eq!(r.rollback.rounds, 1, "{:?}", r.rollback);
    assert_eq!(r.rollback.outvoted, 1, "the lone proposal must be outvoted");
    assert_eq!(r.rollback.committed, 0);
    assert_eq!(r.rollback.proposals, 1);
    assert_eq!(r.recovery.rollbacks, 0, "{:?}", r.recovery);
    assert_eq!(r.recovery.loss_spikes, 0, "a forced vote is not a detector firing");
    assert_eq!(r.losses, clean_report.losses, "an outvoted round must not touch training");
    assert_eq!(r.final_ppl, clean_report.final_ppl);
    assert_params_identical(&clean.model().params, &faulty.model().params, "outvote vs clean");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_serve_lanes_replay_their_requests_token_identically() {
    // Two lane deaths mid-decode under continuous batching with more
    // requests than slots: every killed request is requeued with its
    // sampler RNG and generated prefix intact, so the retried
    // completions match a fault-free engine token for token. TopK
    // sampling makes this a test of the preserved *stream*, not argmax.
    let sampling = Sampling::TopK { k: 8, temperature: 0.9 };
    let run = |plan: Option<FaultPlan>| {
        let mut e = ServeEngine::new(SimModel::new(llama_tiny_cfg(), 5), 2, 32);
        if let Some(p) = plan {
            e.arm_faults(p);
        }
        for i in 0..4u64 {
            e.submit(&[1, i as u32 + 2, 3], 6, sampling, 100 + i).unwrap();
        }
        let mut done = e.run_until_idle();
        done.sort_by_key(|c| c.id);
        let tokens: Vec<Vec<u32>> = done.iter().map(|c| c.tokens.clone()).collect();
        (e, tokens)
    };

    let (_, want) = run(None);
    let (eng, got) = run(Some(FaultPlan::parse("lane0@2,lane1@4", 0).unwrap()));
    assert_eq!(got, want, "requeued completions diverged from the fault-free oracle");
    assert_eq!(eng.fault_stats().lane_kills, 2);
    assert_eq!(eng.requeues(), 2, "each killed lane requeues exactly one request");
}

#[test]
fn serve_reload_survives_a_corrupt_checkpoint_with_a_typed_error() {
    use lotus::train::checkpoint::{save_weights, CkptError};
    let dir = std::env::temp_dir().join("lotus_faults_serve_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let m = SimModel::new(llama_tiny_cfg(), 5);
    let newest = dir.join("new.ckpt");
    let older = dir.join("old.ckpt");
    save_weights(&newest, 8, &m.params).unwrap();
    save_weights(&older, 4, &m.params).unwrap();

    // a single mangled candidate surfaces a typed CRC diagnosis ...
    let mut e = ServeEngine::new(SimModel::new(llama_tiny_cfg(), 5), 1, 16);
    e.arm_faults(FaultPlan::parse("ckpt_corrupt@load", 0).unwrap());
    let err = e.reload_from_chain(&[&newest]).unwrap_err();
    assert!(err.downcast_ref::<CkptError>().is_some(), "typed diagnosis: {err:#}");
    // ... the fault fires once, so the next reload is clean again
    assert_eq!(e.reload_from_chain(&[&newest, &older]).unwrap(), 8);

    // with a fallback in the chain the corrupted load self-recovers
    let mut e = ServeEngine::new(SimModel::new(llama_tiny_cfg(), 5), 1, 16);
    e.arm_faults(FaultPlan::parse("ckpt_corrupt@load", 0).unwrap());
    assert_eq!(e.reload_from_chain(&[&newest, &older]).unwrap(), 4, "fallback container");
    assert_eq!(e.fault_stats().ckpt_corruptions, 1);
    let _ = std::fs::remove_dir_all(&dir);
}
