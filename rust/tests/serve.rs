//! Serving-engine contract tests.
//!
//! 1. KV-cache correctness: greedy incremental decode (prefill + one
//!    token per step) reproduces the teacher-forced full-context
//!    forward *token-for-token*, for every (prompt length, slot count)
//!    combination — the logits-level bit-identity lives next to the
//!    kernel in `sim/model.rs`; this exercises the whole engine.
//! 2. Continuous batching: a request's tokens are unchanged by whatever
//!    else shares its batch, including requests admitted mid-decode.
//! 3. Train → checkpoint → serve round trip: a sim-trainer run saved
//!    through the checkpoint container (full or weights-only) decodes
//!    the same greedy tokens as the in-memory model.
//!
//! CI reruns this suite at `LOTUS_THREADS=1` and `4` — the tokens must
//! not depend on the pool width.

use lotus::models::presets::llama_tiny_cfg;
use lotus::models::LlamaConfig;
use lotus::serve::{sample, Sampling, ServeEngine};
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::sim::SimModel;
use lotus::train::checkpoint;
use lotus::util::Rng;

fn small_cfg() -> LlamaConfig {
    LlamaConfig { vocab: 48, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, seq_len: 8 }
}

/// Greedy reference decode through the full-context forward: no KV
/// cache, the whole sequence re-run every token.
fn teacher_forced_greedy(model: &SimModel, prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut seq = prompt.to_vec();
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let logits = model.forward_logits(&seq, 1, seq.len());
        let tok = sample::argmax(logits.row(seq.len() - 1));
        out.push(tok);
        seq.push(tok);
    }
    out
}

#[test]
fn greedy_decode_matches_full_context_for_every_prompt_length_and_slot_count() {
    let cfg = small_cfg();
    let oracle = SimModel::new(cfg, 21);
    let mut rng = Rng::new(33);
    for plen in [1usize, 2, 5, 9] {
        let prompt: Vec<u32> = (0..plen).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        let want = teacher_forced_greedy(&oracle, &prompt, 6);
        for slots in [1usize, 3] {
            // same seed ⇒ bit-identical weights for the engine's model
            let mut eng = ServeEngine::new(SimModel::new(cfg, 21), slots, 32);
            let id = eng.submit(&prompt, 6, Sampling::Greedy, 0).unwrap();
            // companions of assorted lengths, budgets and samplers
            for j in 0..4u64 {
                let p: Vec<u32> = (0..=(2 * j as usize))
                    .map(|x| ((j * 5 + x as u64 * 3 + 1) % cfg.vocab as u64) as u32)
                    .collect();
                eng.submit(&p, 3 + j as usize, Sampling::TopK { k: 3, temperature: 0.9 }, j)
                    .unwrap();
            }
            let done = eng.run_until_idle();
            assert_eq!(done.len(), 5, "plen={plen} slots={slots}");
            let got = done.iter().find(|c| c.id == id).unwrap();
            assert_eq!(got.tokens, want, "plen={plen} slots={slots}");
            assert_eq!(got.prompt_len, plen);
        }
    }
}

#[test]
fn tokens_are_invariant_to_requests_admitted_mid_decode() {
    let cfg = small_cfg();
    let oracle = SimModel::new(cfg, 22);
    let prompt = [4u32, 40, 11, 7];
    let want = teacher_forced_greedy(&oracle, &prompt, 8);

    let mut eng = ServeEngine::new(SimModel::new(cfg, 22), 2, 32);
    let id = eng.submit(&prompt, 8, Sampling::Greedy, 0).unwrap();
    let mut done = Vec::new();
    // run a couple of steps solo, then inject company mid-decode so the
    // target's later tokens are produced alongside fresh prefills
    eng.step(&mut done);
    eng.step(&mut done);
    eng.submit(&[9, 9, 9, 9, 9, 9, 9], 4, Sampling::TopK { k: 5, temperature: 1.3 }, 7).unwrap();
    eng.step(&mut done);
    eng.submit(&[1, 2], 9, Sampling::Greedy, 1).unwrap();
    done.extend(eng.run_until_idle());

    assert_eq!(done.len(), 3);
    let got = done.iter().find(|c| c.id == id).unwrap();
    assert_eq!(got.tokens, want, "batch composition changed a request's tokens");
    // scheduler stamps are sane: the target was admitted on step 1 and
    // took one engine step per token
    assert_eq!(got.admitted_step, 1);
    assert_eq!(got.finished_step, 8);
}

#[test]
fn train_checkpoint_serve_roundtrip_decodes_identical_tokens() {
    // the acceptance E2E: train → save (full container AND weights-only)
    // → load into the serve engine → greedy tokens equal the in-memory
    // model's teacher-forced decode, for both container flavours
    let model_cfg = llama_tiny_cfg();
    let mut cfg = SimRunCfg::quick(model_cfg, 16, 8);
    cfg.batch = 2;
    cfg.eval_batches = 1;
    let mut t = SimTrainer::new(&cfg, Method::Lotus { gamma: 0.02, eta: 5, t_min: 5 }, 5);
    t.train(8);

    let dir = std::env::temp_dir().join("lotus_serve_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let full = dir.join("full.ckpt");
    let weights = dir.join("weights.ckpt");
    t.save_checkpoint(&full).unwrap();
    checkpoint::save_weights(&weights, t.current_step(), &t.model().params).unwrap();
    // weights-only drops the optimizer moments: the file must be smaller
    let (fs, ws) = (
        std::fs::metadata(&full).unwrap().len(),
        std::fs::metadata(&weights).unwrap().len(),
    );
    assert!(ws < fs, "weights-only ({ws}) not smaller than full ({fs})");

    let prompt = [0u32, 5, 17, 3, 9];
    let want = teacher_forced_greedy(t.model(), &prompt, 12);
    for path in [&full, &weights] {
        let (step, mut eng) = ServeEngine::from_checkpoint(model_cfg, path, 2, 32).unwrap();
        assert_eq!(step, 8, "{path:?}");
        let got = eng.generate(&prompt, 12, Sampling::Greedy, 0).unwrap();
        assert_eq!(got, want, "{path:?}");
    }
    let _ = std::fs::remove_file(full);
    let _ = std::fs::remove_file(weights);
}

#[test]
fn seeded_top_k_requests_are_reproducible_but_seed_sensitive() {
    let cfg = small_cfg();
    let prompt = [3u32, 14, 15];
    let run = |seed: u64| -> Vec<u32> {
        let mut eng = ServeEngine::new(SimModel::new(cfg, 23), 1, 40);
        eng.generate(&prompt, 20, Sampling::TopK { k: 4, temperature: 1.0 }, seed).unwrap()
    };
    assert_eq!(run(9), run(9), "same sampling seed must reproduce the stream");
    assert_ne!(run(9), run(10), "different sampling seeds should diverge within 20 tokens");
}
