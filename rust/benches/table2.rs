//! Table 2 — GLUE-sim fine-tuning: 8 tasks × methods × rank {4, 8},
//! per-task paper metric + average + memory (measured optimizer state +
//! analytic RoBERTa-Base figure).

use lotus::bench::{steps, table2_methods};
use lotus::data::glue::{generate_suite, task_names};
use lotus::memcount;
use lotus::models::presets::{encoder_small_cfg, roberta_base};
use lotus::optim::Hyper;
use lotus::sim::finetune_task;
use lotus::util::fmt::{self, Table};

fn main() {
    let enc = encoder_small_cfg();
    let suite = generate_suite(enc.vocab, enc.seq_len, 1234);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
    let epochs = if steps(4) < 4 { 1 } else { 2 } as usize;

    for rank in [4usize, 8] {
        println!("=== Table 2 (rank={rank}, GLUE-sim, measured) ===\n");
        let mut header: Vec<String> = vec!["Method".into(), "Memory".into()];
        header.extend(task_names().iter().map(|s| s.to_string()));
        header.push("Avg".into());
        let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&hdr_refs);

        for method in table2_methods(100) {
            let mut cells = vec![method.name().to_string()];
            let mut metrics = Vec::new();
            let mut state_bytes = 0u64;
            for task in &suite {
                let r = finetune_task(&enc, task, method, rank, epochs, 8, &hyper, 7);
                metrics.push(r.metric);
                state_bytes = state_bytes.max(r.state_bytes);
                eprintln!("  [{} r{rank}] {}: {:.2} ({:.0}s)", method.name(), task.name, r.metric, r.wall_s);
            }
            cells.push(fmt::bytes(state_bytes));
            let avg = metrics.iter().sum::<f64>() / metrics.len() as f64;
            cells.extend(metrics.iter().map(|m| format!("{m:.2}")));
            cells.push(format!("{avg:.2}"));
            table.row(&cells);
        }
        println!("{}", table.render());
    }

    println!("=== Table 2 memory column (analytic, RoBERTa-Base, f32 states) ===\n");
    let shape = roberta_base();
    let mut mem_table = Table::new(&["Method", "rank=4", "rank=8"]);
    for m in [
        memcount::Method::FullRank,
        memcount::Method::LoRA,
        memcount::Method::GaLore,
        memcount::Method::Apollo,
        memcount::Method::AdaRankGrad,
        memcount::Method::Lotus,
    ] {
        let m4 = memcount::model_mem(m, &shape, 4, 4);
        let m8 = memcount::model_mem(m, &shape, 8, 4);
        mem_table.row(&[
            m.name().to_string(),
            fmt::bytes(m4.opt_state + m4.transient_peak),
            fmt::bytes(m8.opt_state + m8.transient_peak),
        ]);
    }
    println!("{}", mem_table.render());
    println!("paper reference: Full 747M | LoRA 257M | GaLore 253M | Lotus 251M (ordering target)");
}
