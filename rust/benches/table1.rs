//! Table 1 — pre-training perplexity + grad/opt memory across methods
//! and model sizes, on the synthetic C4-like corpus (scaled models; see
//! DESIGN.md §2 for the substitution argument).
//!
//! Prints (a) the measured ppl(state) grid at bench scale and (b) the
//! analytic memory column at the paper's exact sizes (60M…1B, bf16),
//! which is the paper's parenthetical number.

use lotus::bench::{steps, table1_methods, table1_sizes};
use lotus::memcount::{self, Method as MM};
use lotus::models::presets as mp;
use lotus::sim::trainer::SimTrainer;
use lotus::util::fmt::{self, Table};

fn main() {
    println!("=== Table 1 (measured, scaled models, synthetic C4) ===");
    println!("cell = validation ppl (persistent optimizer state)\n");
    let sizes = table1_sizes();
    let methods = table1_methods();

    let mut header: Vec<&str> = vec!["Method"];
    let labels: Vec<String> =
        sizes.iter().map(|(paper, ours, _)| format!("{paper}~{ours}")).collect();
    for l in &labels {
        header.push(l);
    }
    let mut table = Table::new(&header);

    for method in &methods {
        let mut cells = vec![method.name().to_string()];
        for (_, _, cfg) in &sizes {
            let mut run_cfg = *cfg;
            run_cfg.steps = steps(cfg.steps);
            let mut t = SimTrainer::new(&run_cfg, *method, 42);
            let r = t.train(run_cfg.steps);
            cells.push(format!("{:.2}({})", r.final_ppl, fmt::bytes(r.state_bytes)));
            eprintln!(
                "  [{} @ {}] ppl {:.2} state {} switches {} ({:.1}s)",
                method.name(),
                run_cfg.model.d_model,
                r.final_ppl,
                fmt::bytes(r.state_bytes),
                r.stats.subspace_count,
                r.total_s
            );
        }
        table.row(&cells);
    }
    println!("{}", table.render());

    println!("=== Table 1 memory column (analytic, paper sizes, bf16) ===");
    println!("cell = grad + optimizer state, as the paper reports\n");
    let paper_sizes: Vec<(&str, lotus::models::ModelShape, u64)> = vec![
        ("60M", mp::llama_paper_60m(), 128),
        ("130M", mp::llama_paper_130m(), 256),
        ("350M", mp::llama_paper_350m(), 256),
        ("1B", mp::llama_paper_1b(), 512),
    ];
    let mut mem_table = Table::new(&["Method", "60M", "130M", "350M", "1B"]);
    for m in MM::all() {
        let mut cells = vec![m.name().to_string()];
        for (_, shape, r) in &paper_sizes {
            let mem = memcount::model_mem(m, shape, *r, 2);
            cells.push(fmt::bytes(mem.grad_plus_opt()));
        }
        mem_table.row(&cells);
    }
    println!("{}", mem_table.render());
    println!(
        "paper reference @60M: Full 0.36G | GaLore 0.24G | Lotus 0.23G  (shape target: Lotus ≲ GaLore < Full)"
    );
}
