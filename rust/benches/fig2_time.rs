//! Fig. 2 — (a) ETA for pre-training a 3B model per method (measured
//! per-step costs at bench scale + calibrated FLOP-model extrapolation);
//! (b) average fine-tuning wall-clock over the GLUE-sim tasks.

use lotus::bench::{steps, write_csv};
use lotus::data::glue::generate_suite;
use lotus::models::presets::{encoder_small_cfg, llama_paper_3b, llama_tiny_cfg};
use lotus::optim::Hyper;
use lotus::sim::finetune_task;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::train::eta::{calibrate_secs_per_flop, eta_seconds, EtaMethod};
use lotus::util::fmt::{self, Table};

fn main() {
    // ---- (a) ETA extrapolation to 3B ----
    println!("=== Fig 2a: ETA, LLaMA-3B pre-training (extrapolated) ===\n");
    let spf = calibrate_secs_per_flop();
    println!("calibrated testbed speed: {:.2} GFLOP/s\n", 1e-9 / spf);
    let shape = llama_paper_3b();
    let r = 512;
    // Fig 2a's setting: single GPU, layer-wise updates — small token
    // budget per step (batch 4 × seq 1024), where the projector-refresh
    // cost is a visible fraction of each step.
    let tokens_per_step = 4096u64;
    let total_tokens = 1u64 << 30; // ~1B tokens

    // measure the adaptive refresh frequency from a real tiny Lotus run
    let n_steps = steps(120);
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, n_steps);
    cfg.batch = 4;
    let lotus_run =
        SimTrainer::new(&cfg, Method::Lotus { gamma: 0.015, eta: 10, t_min: 10 }, 7).train(n_steps);
    let lotus_freq = (lotus_run.stats.observations as f64
        / lotus_run.stats.subspace_count.max(1) as f64)
        .max(1.0);
    println!("measured Lotus refresh-every (tiny run): {lotus_freq:.0} steps\n");

    let methods = [
        EtaMethod::GaLore { refresh_every: 200.0 },
        EtaMethod::AdaRankGrad { refresh_every: 200.0 },
        EtaMethod::Apollo,
        EtaMethod::Lotus { refresh_every: lotus_freq, oversample: 8, power_iters: 1 },
    ];
    let mut table = Table::new(&["Method", "ETA", "vs GaLore"]);
    let galore_eta = eta_seconds(methods[0], &shape, r, tokens_per_step, total_tokens, spf);
    let mut rows = Vec::new();
    for m in methods {
        let eta = eta_seconds(m, &shape, r, tokens_per_step, total_tokens, spf);
        table.row(&[
            m.name().to_string(),
            fmt::duration_s(eta),
            format!("{:.2}x", eta / galore_eta),
        ]);
        rows.push(format!("{},{eta:.0}", m.name()));
    }
    println!("{}", table.render());
    let path = write_csv("fig2a_eta", "method,eta_seconds", &rows).expect("csv");
    println!("-> {path}\npaper shape target: Lotus fastest; ~30% below GaLore\n");

    // ---- (b) measured fine-tune wall-clock ----
    println!("=== Fig 2b: avg fine-tune time over GLUE-sim (measured) ===\n");
    let enc = encoder_small_cfg();
    let suite = generate_suite(enc.vocab, enc.seq_len, 555);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
    let mut table_b = Table::new(&["Method", "Avg task time", "vs GaLore"]);
    let mut times = Vec::new();
    for (label, method) in [
        ("GaLore", Method::GaLore { interval: 100 }),
        ("AdaRankGrad", Method::AdaRankGrad { interval: 100, decay: 0.85 }),
        ("Apollo", Method::Apollo { refresh_every: 100 }),
        ("Lotus", Method::Lotus { gamma: 0.01, eta: 10, t_min: 10 }),
    ] {
        let mut total_s = 0.0;
        for task in &suite {
            let r = finetune_task(&enc, task, method, 8, 1, 8, &hyper, 3);
            total_s += r.wall_s;
        }
        let avg = total_s / suite.len() as f64;
        eprintln!("  {label}: avg {avg:.2}s/task");
        times.push((label, avg));
    }
    let galore_t = times[0].1;
    let mut rows_b = Vec::new();
    for (label, avg) in &times {
        table_b.row(&[
            label.to_string(),
            fmt::duration_s(*avg),
            format!("{:.2}x", avg / galore_t),
        ]);
        rows_b.push(format!("{label},{avg:.3}"));
    }
    println!("{}", table_b.render());
    let path = write_csv("fig2b_finetune_time", "method,avg_seconds", &rows_b).expect("csv");
    println!("-> {path}\npaper shape target: Lotus < Apollo/AdaRankGrad < GaLore");
}
