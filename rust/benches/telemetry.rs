//! Telemetry overhead bench → `BENCH_telemetry.json`.
//!
//! The observability contract (`rust/src/telemetry/`): with no sink
//! installed an instrumentation site costs one relaxed atomic load, and
//! with spans + Chrome trace enabled a seeded sim run must stay within
//! **2%** of the uninstrumented wall-clock (gated here on min-of-trials;
//! the JSONL metrics sink adds per-step file writes and is reported as
//! an informational number, not gated).
//!
//! `LOTUS_BENCH_FAST=1` trims steps/trials. See EXPERIMENTS.md
//! §Observability.

use lotus::bench::{fast_mode, steps};
use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::telemetry;
use lotus::util::json::JsonValue;

/// One seeded training run (fresh trainer, identical arithmetic every
/// call); returns wall seconds of `train(n)` alone.
fn time_run(cfg: &SimRunCfg, method: Method, n: u64) -> f64 {
    let mut t = SimTrainer::new(cfg, method, cfg.seed);
    let t0 = std::time::Instant::now();
    let r = t.train(n);
    let s = t0.elapsed().as_secs_f64();
    std::hint::black_box(r.final_ppl);
    s
}

fn min_of(trials: usize, mut f: impl FnMut() -> f64) -> f64 {
    (0..trials).map(|_| f()).fold(f64::INFINITY, f64::min)
}

fn main() {
    let n = steps(40);
    let trials = if fast_mode() { 3 } else { 6 };
    let cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, n);
    let method = Method::lotus_default_bench();
    std::fs::create_dir_all("bench_out").expect("bench_out/");

    println!("=== Telemetry overhead bench ({n} steps, min of {trials} trials) ===\n");

    // ---- baseline: no sinks, spans off (the default process state) ----
    telemetry::set_spans_enabled(false);
    let base_s = min_of(trials, || time_run(&cfg, method, n));
    println!("baseline (telemetry off):     {:.4} s", base_s);

    // ---- spans + Chrome trace enabled (the gated configuration) ----
    telemetry::reset_phases();
    telemetry::install_trace("bench_out/BENCH_telemetry_trace.json");
    let traced_s = min_of(trials, || time_run(&cfg, method, n));
    let phase_ns = telemetry::phase_totals_ns();
    let phase_counts = telemetry::phase_counts();
    telemetry::finish().expect("trace flush");
    let trace_overhead_pct = 100.0 * (traced_s - base_s) / base_s;
    println!("spans + trace:                {traced_s:.4} s  ({trace_overhead_pct:+.2}%)");

    // ---- JSONL metrics sink on top (informational, not gated) ----
    telemetry::install_metrics("bench_out/BENCH_telemetry_metrics.jsonl")
        .expect("metrics sink");
    let metrics_s = min_of(trials, || time_run(&cfg, method, n));
    telemetry::finish().expect("metrics flush");
    let metrics_overhead_pct = 100.0 * (metrics_s - base_s) / base_s;
    println!("+ JSONL metrics sink:         {metrics_s:.4} s  ({metrics_overhead_pct:+.2}%)");

    // ---- subspace-quality probes at k=1 on top of the metrics sink ----
    // (informational: per-matrix capture/residual/noise records every
    // step is the heaviest diagnostic configuration; `--probe-every 0`
    // costs one relaxed load and is covered by the baseline above)
    telemetry::install_metrics("bench_out/BENCH_telemetry_probes.jsonl")
        .expect("metrics sink for probe pass");
    telemetry::diag::set_probe_every(1);
    telemetry::diag::set_probes_enabled(true);
    let probes_s = min_of(trials, || time_run(&cfg, method, n));
    telemetry::finish().expect("probe-pass flush"); // also disables probes
    let probe_overhead_pct = 100.0 * (probes_s - base_s) / base_s;
    println!("+ probes (k=1):               {probes_s:.4} s  ({probe_overhead_pct:+.2}%)\n");

    // per-phase view of where the traced run's time went
    let mut phases_json = Vec::new();
    for (i, kind) in telemetry::ALL_KINDS.iter().enumerate() {
        if phase_counts[i] > 0 {
            println!(
                "  {:>16}: {:>10.3} ms over {} spans",
                kind.as_str(),
                phase_ns[i] as f64 / 1e6,
                phase_counts[i]
            );
            phases_json.push((
                kind.as_str(),
                JsonValue::obj(vec![
                    ("total_ns", JsonValue::num(phase_ns[i] as f64)),
                    ("count", JsonValue::num(phase_counts[i] as f64)),
                ]),
            ));
        }
    }

    let doc = JsonValue::obj(vec![
        ("steps", JsonValue::num(n as f64)),
        ("trials", JsonValue::num(trials as f64)),
        ("baseline_s", JsonValue::num(base_s)),
        ("traced_s", JsonValue::num(traced_s)),
        ("metrics_s", JsonValue::num(metrics_s)),
        ("probes_s", JsonValue::num(probes_s)),
        ("trace_overhead_pct", JsonValue::num(trace_overhead_pct)),
        ("metrics_overhead_pct", JsonValue::num(metrics_overhead_pct)),
        ("probe_overhead_pct", JsonValue::num(probe_overhead_pct)),
        ("gate_pct", JsonValue::num(2.0)),
        ("phases", JsonValue::obj(phases_json)),
    ]);
    let path = "BENCH_telemetry.json";
    std::fs::write(path, doc.to_string()).expect("writing BENCH_telemetry.json");
    println!("\nwrote {path}");

    assert!(
        trace_overhead_pct <= 2.0,
        "span+trace overhead {trace_overhead_pct:.2}% exceeds the 2% gate \
         (baseline {base_s:.4}s vs traced {traced_s:.4}s)"
    );
    println!("overhead gate: spans + trace within 2% of uninstrumented wall-clock ✓");
}
