//! Table 3 — subspace count & switching frequency, GaLore vs Lotus,
//! measured over GLUE-sim fine-tuning runs at rank {4, 8}.

use lotus::bench::steps;
use lotus::data::glue::generate_suite;
use lotus::models::presets::encoder_small_cfg;
use lotus::optim::Hyper;
use lotus::sim::finetune_task;
use lotus::sim::trainer::Method;
use lotus::subspace::SubspaceStats;
use lotus::util::fmt::Table;

fn main() {
    let enc = encoder_small_cfg();
    let suite = generate_suite(enc.vocab, enc.seq_len, 99);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
    let epochs = if steps(4) < 4 { 1 } else { 2 } as usize;

    println!("=== Table 3 (measured over the 8 GLUE-sim tasks) ===\n");
    let mut table = Table::new(&["Method", "Subspace Count", "Switch Freq /100 layer-steps"]);
    let mut results: Vec<(String, u64, f64)> = Vec::new();

    for rank in [4usize, 8] {
        for (label, method) in [
            (format!("GaLore (rank={rank})"), Method::GaLore { interval: 100 }),
            (
                format!("Lotus (rank={rank})"),
                Method::Lotus { gamma: 0.01, eta: 10, t_min: 10 },
            ),
        ] {
            let mut agg = SubspaceStats::default();
            for task in &suite {
                let r = finetune_task(&enc, task, method, rank, epochs, 8, &hyper, 11);
                agg.merge(&r.stats);
            }
            eprintln!("  {label}: count {} freq {:.2}", agg.subspace_count, agg.frequency_per_100());
            results.push((label, agg.subspace_count, agg.frequency_per_100()));
        }
    }
    for (label, count, freq) in &results {
        table.row(&[label.clone(), count.to_string(), format!("{freq:.2}")]);
    }
    println!("{}", table.render());

    // the paper's headline: Lotus switches ~3-4x more often than GaLore
    for pair in results.chunks(2) {
        if let [(gl, gc, gf), (ll, lc, lf)] = pair {
            let ratio = lf / gf.max(1e-9);
            println!(
                "{} vs {}: count {}→{}, freq ×{:.1} (paper: ×4.1 at rank 4, ×3.9 at rank 8)",
                gl, ll, gc, lc, ratio
            );
        }
    }
}
