//! Serving throughput: prefill vs decode tokens/s and the
//! continuous-batching speedup, written to `BENCH_serve.json` so the
//! serving trajectory is tracked across PRs (same contract as
//! `BENCH_headline.json` / `BENCH_dist.json`).
//!
//! Gate: batched decode at batch 8 must be ≥ 3× single-stream
//! throughput on ≥ 4 cores with a ≥ 4-wide pool — the whole point of
//! slot batching is that shared-nothing lanes scale across the pool.
//! `LOTUS_THREADS` sets the pool width; `LOTUS_BENCH_FAST=1` trims the
//! token budgets.

use lotus::bench::fast_mode;
use lotus::models::presets::llama_tiny_cfg;
use lotus::runtime::pool;
use lotus::serve::{sample, Sampling, ServeEngine};
use lotus::sim::model::KvCache;
use lotus::sim::SimModel;
use lotus::tensor::{Matrix, Workspace};
use lotus::util::json::JsonValue;
use lotus::util::Rng;
use std::time::Instant;

const BATCH: usize = 8;

/// Steady-state decode throughput (tokens/s) with `slots` concurrent
/// greedy streams: admit + prefill + warm the scratch, then time
/// `steps` pure decode engine steps (one token per slot per step).
fn steady_decode_tps(slots: usize, steps: usize) -> f64 {
    let cfg = llama_tiny_cfg();
    let model = SimModel::new(cfg, 0xA11CE);
    let mut rng = Rng::new(7);
    let prompt: Vec<u32> = (0..16).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
    let max_new = steps + 8; // never retire inside the measured window
    let mut eng = ServeEngine::new(model, slots, prompt.len() + max_new + 1);
    for i in 0..slots {
        eng.submit(&prompt, max_new, Sampling::Greedy, i as u64).unwrap();
    }
    let mut out = Vec::new();
    // prefill + two decode steps to warm every lane's workspace
    for _ in 0..3 {
        eng.step(&mut out);
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        eng.step(&mut out);
    }
    let dt = t0.elapsed().as_secs_f64();
    assert!(out.is_empty(), "a request retired inside the measured window");
    (steps * slots) as f64 / dt
}

fn main() {
    let threads = pool::global().threads();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cfg = llama_tiny_cfg();
    println!("=== Serving throughput (pool: {threads} threads, {cores} cores, llama-tiny) ===\n");

    // ---- prefill vs incremental decode, single stream ----
    let model = SimModel::new(cfg, 0xA11CE);
    let prompt_len = if fast_mode() { 32 } else { 64 };
    let mut rng = Rng::new(1);
    let prompt: Vec<u32> =
        (0..prompt_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
    let n_dec = if fast_mode() { 24 } else { 64 };
    let mut cache = KvCache::new(&cfg, prompt_len + n_dec + 8);
    let mut ws = Workspace::new();
    let mut logits = Matrix::zeros(0, 0);
    model.forward_step(&prompt, &mut cache, &mut ws, &mut logits); // warm
    let reps = 3;
    let t0 = Instant::now();
    for _ in 0..reps {
        cache.clear();
        model.forward_step(&prompt, &mut cache, &mut ws, &mut logits);
    }
    let prefill_tps = (reps * prompt_len) as f64 / t0.elapsed().as_secs_f64();
    let mut tok = sample::argmax(logits.row(0));
    let t0 = Instant::now();
    for _ in 0..n_dec {
        model.forward_step(&[tok], &mut cache, &mut ws, &mut logits);
        tok = sample::argmax(logits.row(0));
    }
    let decode_tps = n_dec as f64 / t0.elapsed().as_secs_f64();
    let _ = tok; // the final sampled token is intentionally unused
    println!(
        "single stream: prefill {prefill_tps:>8.1} tok/s ({prompt_len}-token prompt) | \
         decode {decode_tps:>8.1} tok/s ({n_dec} tokens)"
    );
    println!(
        "prefill/decode ratio: {:.2}x (batched GEMMs amortize per-token overhead)\n",
        prefill_tps / decode_tps
    );

    // ---- batched vs single-stream decode throughput ----
    let steps = if fast_mode() { 32 } else { 96 };
    let single_tps = steady_decode_tps(1, steps);
    let batched_tps = steady_decode_tps(BATCH, steps);
    let speedup = batched_tps / single_tps;
    println!(
        "decode throughput: 1 stream {single_tps:>8.1} tok/s | batch {BATCH} {batched_tps:>8.1} tok/s \
         => {speedup:.2}x"
    );
    let gate_applies = cores >= 4 && threads >= 4;
    if gate_applies {
        assert!(
            speedup >= 3.0,
            "batched decode at batch {BATCH} must be >= 3x single-stream on >= 4 cores \
             (got {speedup:.2}x)"
        );
    } else {
        println!("(speedup gate skipped: needs >= 4 cores and a >= 4-wide pool)");
    }

    // ---- machine-readable record ----
    let doc = JsonValue::obj(vec![
        ("threads", JsonValue::num(threads as f64)),
        ("cores", JsonValue::num(cores as f64)),
        ("model", JsonValue::str("llama-tiny")),
        ("prompt_len", JsonValue::num(prompt_len as f64)),
        ("prefill_tokens_per_s", JsonValue::num(prefill_tps)),
        ("decode_tokens_per_s", JsonValue::num(decode_tps)),
        ("batch", JsonValue::num(BATCH as f64)),
        ("single_stream_tokens_per_s", JsonValue::num(single_tps)),
        ("batched_tokens_per_s", JsonValue::num(batched_tps)),
        ("batched_speedup", JsonValue::num(speedup)),
        ("speedup_gate_applied", JsonValue::Bool(gate_applies)),
    ]);
    let path = "BENCH_serve.json";
    std::fs::write(path, doc.to_string()).expect("writing BENCH_serve.json");
    println!("\nwrote {path}");
}
