//! Distributed data-parallel headline bench → `BENCH_dist.json`.
//!
//! Asserts the two ISSUE 2 acceptance gates and records the evidence:
//!
//! 1. **Bit-identity**: N-worker runs (N = 1, 2, 4 over the same 4
//!    canonical shards) produce identical per-step losses, switch
//!    schedules and final weights.
//! 2. **Comm volume**: the steady-state all-reduce traffic of the
//!    low-rank exchange is ≥ (m/r)× below the dense-gradient baseline
//!    (m = d_model, the projected short dimension at tiny scale) —
//!    measured against a real `Method::FullRank` dist run and
//!    cross-checked against the analytic model in `memcount`.
//!
//! `LOTUS_BENCH_FAST=1` trims the step count. See `EXPERIMENTS.md`
//! §Scale for methodology.

use lotus::bench::steps;
use lotus::dist::comm::tree_reduce_with;
use lotus::dist::{tree_reduce_hardened, CommStats, DistCfg, DistTrainer, Topology};
use lotus::memcount;
use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::{Method, SimRunCfg};
use lotus::telemetry::Histogram;
use lotus::util::json::JsonValue;
use lotus::util::Rng;

/// Time one tree reduction over `slots` payloads of `payload` floats,
/// `trials` times; per-call latencies land in `hist`, the minimum (the
/// least-perturbed sample) is returned in nanoseconds.
fn time_reduce(payload: usize, slots: usize, trials: usize, hardened: bool, hist: &Histogram) -> u64 {
    let topo = Topology::new(slots, 1);
    let mut rng = Rng::new(0xBE9C);
    let base: Vec<Vec<f32>> = (0..slots)
        .map(|_| (0..payload).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let mut items = base.clone();
    let mut stats = CommStats::default();
    let mut best = u64::MAX;
    for _ in 0..trials {
        for (dst, src) in items.iter_mut().zip(&base) {
            dst.copy_from_slice(src);
        }
        let t0 = std::time::Instant::now();
        if hardened {
            tree_reduce_hardened(&mut items, |v| &mut v[..], &topo, None, &mut stats)
                .expect("fault-free reduction cannot fail");
        } else {
            tree_reduce_with(&mut items, |v| &mut v[..], &topo);
        }
        let ns = t0.elapsed().as_nanos() as u64;
        std::hint::black_box(&items);
        hist.record(ns);
        best = best.min(ns);
    }
    best
}

fn run(
    cfg: &SimRunCfg,
    method: Method,
    workers: usize,
    shards: usize,
    n: u64,
) -> (lotus::dist::DistReport, Vec<f32>) {
    let mut t = DistTrainer::new(cfg, method, DistCfg { workers, shards, quorum: 0.5 }, 17)
        .expect("dist trainer");
    let r = t.train(n);
    // weight fingerprint: embedding + first/last layer attention/ffn
    let p = &t.model().params;
    let mut fp = Vec::new();
    fp.extend_from_slice(&p.embed.data[..64.min(p.embed.data.len())]);
    fp.extend_from_slice(&p.layers[0].wq.data[..64]);
    fp.extend_from_slice(&p.layers[p.layers.len() - 1].w2.data[..64]);
    (r, fp)
}

fn main() {
    let n = steps(40);
    let shards = 4usize;
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, n);
    cfg.batch = 8;
    cfg.eval_every = n; // one mid eval + the final one
    cfg.eval_batches = 2;
    let method = Method::Lotus { gamma: 0.5, eta: 5, t_min: 5 };

    println!("=== Distributed data-parallel bench ({n} steps, {shards} shards) ===\n");

    // ---- gate 1: worker-count bit-identity ----
    let worker_counts = [1usize, 2, 4];
    let mut runs = Vec::new();
    for &w in &worker_counts {
        let (r, fp) = run(&cfg, method, w, shards, n);
        println!(
            "N={w}: ppl {:.2} | subspaces {} | consensus {}/{} | lowrank {} refresh {} dense {}",
            r.final_ppl,
            r.stats.subspace_count,
            r.consensus.triggered,
            r.consensus.rounds,
            r.comm.lowrank_bytes,
            r.comm.refresh_dense_bytes,
            r.comm.other_dense_bytes,
        );
        runs.push((w, r, fp));
    }
    let (_, r1, fp1) = &runs[0];
    for (w, r, fp) in &runs[1..] {
        assert_eq!(&r.losses, &r1.losses, "N={w} losses diverged from N=1");
        assert_eq!(&r.switch_steps, &r1.switch_steps, "N={w} switch schedule diverged");
        assert_eq!(r.final_ppl, r1.final_ppl, "N={w} ppl diverged");
        assert!(fp == fp1, "N={w} weights diverged from N=1");
    }
    println!("\nbit-identity: N=2 and N=4 match N=1 exactly on the same total batch ✓\n");

    // ---- gate 2: comm volume vs the dense baseline ----
    let r4 = &runs[2].1;
    let (dense_run, _) = run(&cfg, Method::FullRank, 4, shards, n);
    let steady = r4.comm.steady_reduction_vs_dense();
    let end_to_end = r4.comm.reduction_vs_dense();
    let target = (cfg.model.d_model / cfg.rank) as f64; // min(m,n)/r for every tiny matrix
    println!(
        "comm (N=4): steady {steady:.2}x below dense baseline (target (m/r) = {target:.0}x), {end_to_end:.2}x end-to-end incl. consensus refreshes"
    );
    println!(
        "dense baseline run moved {} bytes for the same matrices (measured FullRank dist)",
        dense_run.comm.other_dense_bytes,
    );
    assert!(
        steady >= target - 1e-9,
        "steady all-reduce saving {steady:.3}x below the (m/r) = {target}x gate"
    );
    assert!(end_to_end > 1.0, "low-rank exchange must beat dense end-to-end");

    // analytic cross-check (memcount twin of the measured accounting)
    let shape = cfg.model.shape("tiny");
    let analytic =
        memcount::model_allreduce_bytes(memcount::Method::Lotus, &shape, cfg.rank as u64, 4);
    println!(
        "analytic per-reduction payload: projected {} vs dense-equiv {} ({:.2}x)",
        analytic.projected,
        analytic.projected_dense_equiv,
        analytic.reduction_vs_dense()
    );

    // ---- measured checksum overhead (ROADMAP §PR 6 follow-up) ----
    // The hardening claim used to rest on an analytic "<5%" estimate;
    // measure it instead: the same tree reduction with and without the
    // sender-side payload checksums, faults unarmed (the steady-state
    // configuration every fault-free run pays). Per-call latencies go
    // through the telemetry histogram; minimums give the least-noisy
    // ratio. Reported, not gated — wall-clock gates flake in CI.
    let trials = if lotus::bench::fast_mode() { 50 } else { 300 };
    let r_payload = cfg.rank * cfg.model.d_model; // r×n projected payload
    let d_payload = cfg.model.d_model * cfg.model.d_ff; // dense refresh payload
    let hard_hist = Histogram::new();
    let plain_hist = Histogram::new();
    let mut overhead_json = Vec::new();
    println!();
    for (label, payload) in [("lowrank_r_x_n", r_payload), ("dense_d_x_ff", d_payload)] {
        let plain_ns = time_reduce(payload, shards, trials, false, &plain_hist);
        let hard_ns = time_reduce(payload, shards, trials, true, &hard_hist);
        let overhead_pct = 100.0 * (hard_ns as f64 - plain_ns as f64) / plain_ns as f64;
        println!(
            "checksum overhead [{label}]: plain {plain_ns} ns vs hardened {hard_ns} ns \
             ({overhead_pct:+.2}% on {payload} floats, min of {trials})"
        );
        overhead_json.push((
            label,
            JsonValue::obj(vec![
                ("payload_floats", JsonValue::num(payload as f64)),
                ("plain_min_ns", JsonValue::num(plain_ns as f64)),
                ("hardened_min_ns", JsonValue::num(hard_ns as f64)),
                ("overhead_pct", JsonValue::num(overhead_pct)),
            ]),
        ));
    }

    // ---- machine-readable record ----
    let runs_json: Vec<JsonValue> = runs
        .iter()
        .map(|(w, r, _)| {
            JsonValue::obj(vec![
                ("workers", JsonValue::num(*w as f64)),
                ("final_ppl", JsonValue::num(r.final_ppl)),
                ("subspaces", JsonValue::num(r.stats.subspace_count as f64)),
                ("consensus_rounds", JsonValue::num(r.consensus.rounds as f64)),
                ("consensus_triggered", JsonValue::num(r.consensus.triggered as f64)),
                ("lowrank_bytes", JsonValue::num(r.comm.lowrank_bytes as f64)),
                ("refresh_dense_bytes", JsonValue::num(r.comm.refresh_dense_bytes as f64)),
                ("other_dense_bytes", JsonValue::num(r.comm.other_dense_bytes as f64)),
                ("dense_equiv_bytes", JsonValue::num(r.comm.dense_equiv_bytes as f64)),
            ])
        })
        .collect();
    let doc = JsonValue::obj(vec![
        ("steps", JsonValue::num(n as f64)),
        ("shards", JsonValue::num(shards as f64)),
        ("bit_identical", JsonValue::Bool(true)), // asserted above
        ("steady_reduction_vs_dense", JsonValue::num(steady)),
        ("end_to_end_reduction_vs_dense", JsonValue::num(end_to_end)),
        ("target_m_over_r", JsonValue::num(target)),
        (
            "analytic",
            JsonValue::obj(vec![
                ("projected_payload", JsonValue::num(analytic.projected as f64)),
                (
                    "projected_dense_equiv",
                    JsonValue::num(analytic.projected_dense_equiv as f64),
                ),
                ("other_dense_payload", JsonValue::num(analytic.other_dense as f64)),
            ]),
        ),
        ("runs", JsonValue::arr(runs_json)),
        (
            "checksum_overhead",
            JsonValue::obj(vec![
                ("trials", JsonValue::num(trials as f64)),
                ("by_payload", JsonValue::obj(overhead_json)),
                ("hardened_ns_hist", hard_hist.to_json()),
                ("plain_ns_hist", plain_hist.to_json()),
            ]),
        ),
    ]);
    let path = "BENCH_dist.json";
    std::fs::write(path, doc.to_string()).expect("writing BENCH_dist.json");
    println!("\nwrote {path}");
}
