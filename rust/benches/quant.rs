//! Quantization engine headline bench → `BENCH_quant.json`.
//!
//! Asserts the PR 8 acceptance gates and records the evidence:
//!
//! 1. **Wire bytes**: the int8 wire moves ≥ 3× fewer bytes than f32 for
//!    the same training run (measured `CommStats`, not analytic), and
//!    bf16 moves ~2× fewer.
//! 2. **Worker bit-identity**: quantized-wire runs (bf16 and int8) are
//!    bit-identical across N = 1, 2, 4 workers on the same 4 shards.
//! 3. **KV bytes**: a bf16 serving engine holds ~2× fewer K/V cache
//!    bytes and still decodes deterministically.
//! 4. **Loss drift**: the int8-wire final loss stays within 15% of the
//!    f32 baseline at tiny scale (reported; the drift itself is the
//!    evidence line).
//!
//! `LOTUS_BENCH_FAST=1` trims step counts. See `EXPERIMENTS.md`
//! §Quantization for methodology.

use lotus::bench::steps;
use lotus::dist::{DistCfg, DistTrainer};
use lotus::models::presets::llama_tiny_cfg;
use lotus::quant::{Codec, QuantDtype};
use lotus::serve::{Sampling, ServeEngine};
use lotus::sim::trainer::{Method, SimRunCfg};
use lotus::sim::SimModel;
use lotus::util::json::JsonValue;
use lotus::util::Rng;

fn run(cfg: &SimRunCfg, workers: usize, n: u64) -> (lotus::dist::DistReport, Vec<f32>) {
    let method = Method::Lotus { gamma: 0.5, eta: 5, t_min: 5 };
    let mut t = DistTrainer::new(cfg, method, DistCfg { workers, shards: 4, quorum: 0.5 }, 17)
        .expect("dist trainer");
    let r = t.train(n);
    let p = &t.model().params;
    let mut fp = Vec::new();
    fp.extend_from_slice(&p.embed.data[..64.min(p.embed.data.len())]);
    fp.extend_from_slice(&p.layers[0].wq.data[..64]);
    fp.extend_from_slice(&p.layers[p.layers.len() - 1].w2.data[..64]);
    (r, fp)
}

fn wire_bytes(r: &lotus::dist::DistReport) -> u64 {
    r.comm.lowrank_bytes + r.comm.refresh_dense_bytes + r.comm.other_dense_bytes
}

/// Codec encode+decode throughput on one payload size (min-of-trials).
fn codec_ns(dtype: QuantDtype, n: usize, trials: usize) -> (u64, u64) {
    let c = Codec::new(dtype, 64);
    let mut rng = Rng::new(0x9A27);
    let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut bytes = Vec::new();
    let mut out = vec![0.0f32; n];
    let (mut enc_best, mut dec_best) = (u64::MAX, u64::MAX);
    for _ in 0..trials {
        let t0 = std::time::Instant::now();
        c.encode_into_pooled(&xs, &mut bytes).unwrap();
        enc_best = enc_best.min(t0.elapsed().as_nanos() as u64);
        let t1 = std::time::Instant::now();
        c.decode_into_pooled(&bytes, &mut out).unwrap();
        dec_best = dec_best.min(t1.elapsed().as_nanos() as u64);
        std::hint::black_box(&out);
    }
    (enc_best, dec_best)
}

fn main() {
    let n = steps(40);
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, n);
    cfg.batch = 8;
    cfg.eval_every = n;
    cfg.eval_batches = 2;

    println!("=== Quantization bench ({n} steps, 4 shards) ===\n");

    // ---- gate 1 + 4: wire bytes and loss drift across dtypes ----
    let mut dtype_json = Vec::new();
    let mut by_dtype = Vec::new();
    for wire in [QuantDtype::F32, QuantDtype::Bf16, QuantDtype::Int8] {
        let mut c = cfg;
        c.quant.wire = wire;
        let (r, fp) = run(&c, 4, n);
        println!(
            "wire {:4}: ppl {:.2} | final loss {:.4} | wire bytes {}",
            wire.as_str(),
            r.final_ppl,
            r.losses.last().unwrap(),
            wire_bytes(&r),
        );
        by_dtype.push((wire, r, fp));
    }
    let f32_bytes = wire_bytes(&by_dtype[0].1);
    let f32_loss = *by_dtype[0].1.losses.last().unwrap();
    for (wire, r, _) in &by_dtype {
        let ratio = f32_bytes as f64 / wire_bytes(r) as f64;
        let loss = *r.losses.last().unwrap();
        let drift = (loss - f32_loss).abs() / f32_loss.abs();
        println!(
            "wire {:4}: {ratio:.2}x fewer bytes than f32 | loss drift {:.2}%",
            wire.as_str(),
            100.0 * drift
        );
        match wire {
            QuantDtype::Int8 => {
                assert!(ratio >= 3.0, "int8 wire reduction {ratio:.2}x below the 3x gate");
                assert!(drift < 0.15, "int8 final-loss drift {drift:.3} above 15% tolerance");
            }
            QuantDtype::Bf16 => {
                assert!((1.9..=2.1).contains(&ratio), "bf16 wire ratio {ratio:.2}x not ~2x");
            }
            QuantDtype::F32 => {}
        }
        dtype_json.push((
            wire.as_str(),
            JsonValue::obj(vec![
                ("wire_bytes", JsonValue::num(wire_bytes(r) as f64)),
                ("bytes_ratio_vs_f32", JsonValue::num(ratio)),
                ("final_loss", JsonValue::num(loss)),
                ("loss_drift_vs_f32", JsonValue::num(drift)),
                ("final_ppl", JsonValue::num(r.final_ppl)),
            ]),
        ));
    }
    println!();

    // ---- gate 2: worker bit-identity under quantized wire ----
    for wire in [QuantDtype::Bf16, QuantDtype::Int8] {
        let mut c = cfg;
        c.quant.wire = wire;
        let bi = steps(16).min(n);
        let (r1, fp1) = run(&c, 1, bi);
        let (r2, fp2) = run(&c, 2, bi);
        let (r4, fp4) = run(&c, 4, bi);
        assert_eq!(r1.losses, r2.losses, "{wire:?}: N=2 losses diverged");
        assert_eq!(r1.losses, r4.losses, "{wire:?}: N=4 losses diverged");
        assert!(fp1 == fp2 && fp1 == fp4, "{wire:?}: weights diverged across workers");
        println!("bit-identity at {} wire: N=1/2/4 agree exactly ✓", wire.as_str());
    }
    println!();

    // ---- gate 3: bf16 KV cache ----
    // same seed → identical weights in both engines
    let kv_f32 = ServeEngine::new(SimModel::new(cfg.model, 3), 4, 32).kv_bytes();
    let mut eng = ServeEngine::with_kv_dtype(SimModel::new(cfg.model, 3), 4, 32, QuantDtype::Bf16);
    let kv_bf16 = eng.kv_bytes();
    let kv_ratio = kv_f32 as f64 / kv_bf16 as f64;
    let a = eng.generate(&[1, 2, 3, 4], 8, Sampling::Greedy, 5).unwrap();
    let b = eng.generate(&[1, 2, 3, 4], 8, Sampling::Greedy, 5).unwrap();
    assert_eq!(a, b, "bf16 KV decode must be deterministic");
    assert!((1.9..=2.1).contains(&kv_ratio), "bf16 KV ratio {kv_ratio:.2}x not ~2x");
    println!("kv cache: f32 {kv_f32} B vs bf16 {kv_bf16} B ({kv_ratio:.2}x) ✓\n");

    // ---- codec throughput (reported, not gated) ----
    let trials = if lotus::bench::fast_mode() { 20 } else { 100 };
    let payload = 1 << 18; // 256k floats ≈ a tiny-model layer gradient
    let mut codec_json = Vec::new();
    for dtype in [QuantDtype::Bf16, QuantDtype::Int8] {
        let (enc, dec) = codec_ns(dtype, payload, trials);
        let gbs = |ns: u64| (payload as f64 * 4.0) / ns as f64; // f32-side GB/s
        println!(
            "codec {:4}: encode {enc} ns ({:.2} GB/s) decode {dec} ns ({:.2} GB/s), {payload} floats",
            dtype.as_str(),
            gbs(enc),
            gbs(dec),
        );
        codec_json.push((
            dtype.as_str(),
            JsonValue::obj(vec![
                ("payload_floats", JsonValue::num(payload as f64)),
                ("encode_min_ns", JsonValue::num(enc as f64)),
                ("decode_min_ns", JsonValue::num(dec as f64)),
            ]),
        ));
    }

    let doc = JsonValue::obj(vec![
        ("steps", JsonValue::num(n as f64)),
        ("shards", JsonValue::num(4.0)),
        ("wire", JsonValue::obj(dtype_json)),
        ("worker_bit_identity", JsonValue::Bool(true)), // asserted above
        (
            "kv_cache",
            JsonValue::obj(vec![
                ("f32_bytes", JsonValue::num(kv_f32 as f64)),
                ("bf16_bytes", JsonValue::num(kv_bf16 as f64)),
                ("ratio", JsonValue::num(kv_ratio)),
            ]),
        ),
        ("codec", JsonValue::obj(codec_json)),
    ]);
    let path = "BENCH_quant.json";
    std::fs::write(path, doc.to_string()).expect("writing BENCH_quant.json");
    println!("\nwrote {path}");
}
