//! The §3.2 complexity claim: randomized SVD vs exact (Jacobi) SVD,
//! time vs matrix size at fixed rank. Prints the sweep + crossover and
//! emits CSV. This is the microbenchmark behind Lotus's 30% end-to-end
//! training-time reduction.

use lotus::bench::write_csv;
use lotus::linalg::rsvd::{rsvd_range, RsvdOpts};
use lotus::linalg::svd::svd_jacobi;
use lotus::tensor::Matrix;
use lotus::util::timer::BenchRunner;
use lotus::util::{fmt, Rng};

fn main() {
    println!("=== rSVD vs exact SVD (rank 16, oversample 4, q=1) ===\n");
    let runner = BenchRunner::new(1, 3);
    let mut rng = Rng::new(31337);
    let mut rows = Vec::new();
    println!(
        "{:>6} {:>12} {:>12} {:>9}",
        "d", "svd(median)", "rsvd(median)", "speedup"
    );
    for &d in &[64usize, 128, 192, 256, 384, 512] {
        let a = Matrix::randn(d, d, 1.0, &mut rng);
        let svd_stats = runner.run(|| svd_jacobi(&a));
        let mut rng_r = Rng::new(7);
        let rsvd_stats = runner.run(|| {
            rsvd_range(&a, RsvdOpts { rank: 16, oversample: 4, power_iters: 1 }, &mut rng_r)
        });
        let speedup = svd_stats.median / rsvd_stats.median;
        println!(
            "{:>6} {:>12} {:>12} {:>8.1}x",
            d,
            fmt::duration_s(svd_stats.median),
            fmt::duration_s(rsvd_stats.median),
            speedup
        );
        rows.push(format!("{d},{},{},{speedup:.2}", svd_stats.median, rsvd_stats.median));
    }
    let path = write_csv("rsvd_speed", "dim,svd_s,rsvd_s,speedup", &rows).expect("csv");
    println!("\n-> {path}");
    println!("shape target: speedup grows with d (SVD is O(d³) w/ large constant, rSVD O(r·d²)).");
}
