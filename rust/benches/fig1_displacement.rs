//! Fig. 1 — unit-gradient displacement traces + switch events under the
//! fixed (GaLore) and adaptive (Lotus) policies, on a real tiny
//! pre-training run. Emits CSV to bench_out/ for re-plotting.

use lotus::bench::{steps, write_csv};
use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};

fn main() {
    let n_steps = steps(240);
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, n_steps);
    cfg.batch = 4;

    println!("=== Fig 1 (displacement diagnostic traces, layer 0) ===\n");
    for (label, method) in [
        ("lotus", Method::Lotus { gamma: 0.015, eta: 10, t_min: 10 }),
        ("fixed", Method::GaLore { interval: 60 }),
    ] {
        let mut t = SimTrainer::new(&cfg, method, 2024);
        let r = t.train(n_steps);
        // diag trace is the policy's ‖d̄‖ (Lotus) — fixed policy has none,
        // so we log its switch steps only.
        let rows: Vec<String> = r
            .diag_trace
            .iter()
            .map(|(s, d)| format!("{s},{d:.6}"))
            .collect();
        if !rows.is_empty() {
            let path = write_csv(&format!("fig1_{label}_diag"), "step,avg_displacement", &rows)
                .expect("csv");
            println!("{label}: {} diagnostic points -> {path}", rows.len());
        }
        let srows: Vec<String> = r.switch_steps.iter().map(|s| s.to_string()).collect();
        let path = write_csv(&format!("fig1_{label}_switches"), "switch_step", &srows).expect("csv");
        println!(
            "{label}: {} switches on layer 0 (total {} across layers) -> {path}",
            srows.len(),
            r.stats.subspace_count
        );
        // textual sparkline of switch events
        let mut line = vec![b'-'; (n_steps as usize).min(120)];
        for s in &r.switch_steps {
            let idx = (*s as usize * line.len() / n_steps as usize).min(line.len() - 1);
            line[idx] = b'S';
        }
        println!("  [{}]\n", String::from_utf8_lossy(&line));
    }
    println!("shape target: adaptive switches cluster where ‖d̄‖ < γ; fixed switches are equidistant.");
}
