//! Table 4 — component ablation: {exact SVD, rSVD} × {fixed, AdaSS}
//! at rank {4, 8}, average GLUE-sim metric. Shows (paper's claim) that
//! rSVD matches exact SVD at equal rank and AdaSS provides the gain.

use lotus::bench::steps;
use lotus::data::glue::generate_suite;
use lotus::models::presets::encoder_small_cfg;
use lotus::optim::Hyper;
use lotus::sim::finetune_task;
use lotus::sim::trainer::Method;
use lotus::util::fmt::Table;

fn main() {
    let enc = encoder_small_cfg();
    let suite = generate_suite(enc.vocab, enc.seq_len, 4321);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
    let epochs = if steps(4) < 4 { 1 } else { 2 } as usize;

    println!("=== Table 4 (ablation, GLUE-sim average) ===\n");
    let mut table = Table::new(&["Rank", "rSVD", "AdaSS", "Avg"]);

    for rank in [4usize, 8] {
        let rows: [(&str, &str, Method); 3] = [
            ("", "", Method::GaLore { interval: 100 }),          // SVD + fixed
            ("x", "", Method::RsvdFixed { interval: 100 }),      // rSVD + fixed
            ("x", "x", Method::Lotus { gamma: 0.01, eta: 10, t_min: 10 }), // full Lotus
        ];
        for (rsvd, adass, method) in rows {
            let mut total = 0.0;
            for task in &suite {
                let r = finetune_task(&enc, task, method, rank, epochs, 8, &hyper, 13);
                total += r.metric;
            }
            let avg = total / suite.len() as f64;
            eprintln!("  rank {rank} rsvd={rsvd:1} adass={adass:1}: avg {avg:.2}");
            table.row(&[
                rank.to_string(),
                rsvd.to_string(),
                adass.to_string(),
                format!("{avg:.2}"),
            ]);
        }
    }
    println!("{}", table.render());
    println!("paper reference (rank 4): 85.89 / 85.89 / 87.28 — rSVD ≈ SVD; AdaSS adds the gain");
}
