//! The paper's headline claims, measured:
//!   * ~30 % training-time reduction vs GaLore,
//!   * ~40 % grad+optimizer memory reduction (vs full-rank; Table 1's
//!     accounting), plus the refresh-transient saving vs GaLore.

use lotus::bench::steps;
use lotus::memcount;
use lotus::models::presets::{llama_paper_1b, llama_paper_60m, llama_tiny_cfg};
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};

fn main() {
    println!("=== Headline claims ===\n");

    // ---- time vs GaLore (measured; both via the sim path) ----
    let n = steps(120);
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, n);
    cfg.batch = 4;
    // GaLore's interval chosen as in its paper (200 ⇒ scaled to run len)
    let galore = SimTrainer::new(&cfg, Method::GaLore { interval: 40 }, 1).train(n);
    let lotus =
        SimTrainer::new(&cfg, Method::Lotus { gamma: 0.015, eta: 10, t_min: 10 }, 1).train(n);
    // compare the *update* phase (fwd/bwd is method-independent)
    let dt = 1.0 - lotus.time_update_s / galore.time_update_s;
    println!(
        "update-phase time: GaLore {:.2}s vs Lotus {:.2}s  (reduction {:.0}% — paper: ~30% end-to-end)",
        galore.time_update_s,
        lotus.time_update_s,
        dt * 100.0
    );
    let total_dt = 1.0 - lotus.total_s / galore.total_s;
    println!(
        "total time:        GaLore {:.2}s vs Lotus {:.2}s  (reduction {:.0}%)",
        galore.total_s,
        lotus.total_s,
        total_dt * 100.0
    );
    println!(
        "ppl:               GaLore {:.2} vs Lotus {:.2}  (target: Lotus <= GaLore)\n",
        galore.final_ppl, lotus.final_ppl
    );

    // ---- memory (analytic at paper sizes) ----
    for (label, shape, r) in
        [("60M", llama_paper_60m(), 128u64), ("1B", llama_paper_1b(), 512u64)]
    {
        let vs_full = memcount::lotus_vs_full_ratio(&shape, r, 2);
        let vs_galore = memcount::lotus_vs_galore_ratio(&shape, r, 2);
        let g = memcount::model_mem(memcount::Method::GaLore, &shape, r, 2);
        let l = memcount::model_mem(memcount::Method::Lotus, &shape, r, 2);
        println!(
            "{label}: grad+opt vs full-rank = {:.0}% saved (paper ~40%) | refresh transient: GaLore {} → Lotus {} ({:.0}% smaller) | opt+transient vs GaLore = {:.1}% saved",
            (1.0 - vs_full) * 100.0,
            lotus::util::fmt::bytes(g.transient_peak),
            lotus::util::fmt::bytes(l.transient_peak),
            (1.0 - l.transient_peak as f64 / g.transient_peak as f64) * 100.0,
            (1.0 - vs_galore) * 100.0,
        );
    }
}
