//! The paper's headline claims plus the engine's perf trajectory,
//! measured and written to `BENCH_headline.json` (machine-readable, one
//! file per run) so speedups are tracked across PRs:
//!   * serial vs pooled matmul GFLOP/s at 512–4096 (pooled must be ≥ 2×
//!     serial at 1024³ on ≥ 4 cores — asserted),
//!   * serial vs pooled rSVD range-finder throughput,
//!   * sim-trainer steps/s,
//!   * ~30 % training-time reduction vs GaLore and the ~40 % grad+opt
//!     memory reduction (Table 1 accounting).
//!
//! Invocations and the expected-speedup table: `EXPERIMENTS.md` §Perf.
//! `LOTUS_THREADS` sets the pool width; `LOTUS_BENCH_FAST=1` trims the
//! large sizes.

use lotus::bench::{fast_mode, steps};
use lotus::linalg::matmul::matmul_into;
use lotus::linalg::par::matmul_into_pooled;
use lotus::linalg::rsvd::{rsvd_flops, rsvd_range_into, RsvdOpts, RsvdScratch};
use lotus::memcount;
use lotus::models::presets::{llama_paper_1b, llama_paper_60m, llama_tiny_cfg};
use lotus::runtime::pool::{self, Pool};
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::tensor::Matrix;
use lotus::util::json::JsonValue;
use lotus::util::timer::BenchRunner;
use lotus::util::Rng;

fn runner_for(n: usize) -> BenchRunner {
    if n >= 2048 {
        BenchRunner::new(0, 1)
    } else {
        BenchRunner::new(1, 3)
    }
}

/// Median GFLOP/s of `C = A·B` at n×n×n, serial or pooled.
fn matmul_gflops(pool: Option<&Pool>, n: usize, rng: &mut Rng) -> f64 {
    let a = Matrix::randn(n, n, 1.0, rng);
    let b = Matrix::randn(n, n, 1.0, rng);
    let mut c = Matrix::zeros(n, n);
    let stats = runner_for(n).run(|| match pool {
        Some(p) => matmul_into_pooled(p, &a, &b, &mut c),
        None => matmul_into(&a, &b, &mut c),
    });
    2.0 * (n as f64).powi(3) / stats.median / 1e9
}

/// Median GFLOP/s of the rSVD range finder at n×n over `pool`. Both the
/// serial baseline (1-thread pool) and the pooled run go through the
/// same scratch-backed engine, so the reported speedup isolates pooling
/// rather than conflating it with allocation savings.
fn rsvd_gflops(pool: &Pool, n: usize, opts: RsvdOpts, rng: &mut Rng) -> f64 {
    let a = Matrix::randn(n, n, 1.0, rng);
    let flops = rsvd_flops(n, n, opts.rank, opts.oversample, opts.power_iters) as f64;
    let mut scratch = RsvdScratch::new();
    let mut out = Matrix::zeros(0, 0);
    let mut r = Rng::new(7);
    let stats = runner_for(n).run(move || {
        rsvd_range_into(&a, opts, &mut r, pool, &mut scratch, &mut out);
    });
    flops / stats.median / 1e9
}

fn main() {
    let threads = pool::global().threads();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("=== Headline claims (pool: {threads} threads, {cores} cores) ===\n");
    let mut rng = Rng::new(0xBEEF);

    // ---- serial vs pooled matmul GFLOP/s ----
    let sizes: &[usize] = if fast_mode() { &[512, 1024] } else { &[512, 1024, 2048, 4096] };
    let mut matmul_rows = Vec::new();
    let mut speedup_1024 = f64::NAN;
    println!("{:>6} {:>14} {:>14} {:>9}", "n", "serial GF/s", "pooled GF/s", "speedup");
    for &n in sizes {
        let serial = matmul_gflops(None, n, &mut rng);
        let pooled = matmul_gflops(Some(pool::global()), n, &mut rng);
        let speedup = pooled / serial;
        if n == 1024 {
            speedup_1024 = speedup;
        }
        println!("{n:>6} {serial:>14.2} {pooled:>14.2} {speedup:>8.2}x");
        matmul_rows.push(JsonValue::obj(vec![
            ("n", JsonValue::num(n as f64)),
            ("serial_gflops", JsonValue::num(serial)),
            ("pooled_gflops", JsonValue::num(pooled)),
            ("speedup", JsonValue::num(speedup)),
        ]));
    }
    // Acceptance gate: ≥ 2× at 1024³ when the machine has ≥ 4 cores.
    let gate_applies = cores >= 4 && threads >= 4;
    if gate_applies {
        assert!(
            speedup_1024 >= 2.0,
            "pooled matmul at 1024 must be >= 2x serial on >= 4 cores (got {speedup_1024:.2}x)"
        );
    }
    println!();

    // ---- serial vs pooled rSVD range finder ----
    let opts = RsvdOpts { rank: 64, oversample: 8, power_iters: 1 };
    let rsvd_sizes: &[usize] = if fast_mode() { &[512] } else { &[512, 1024, 2048] };
    let mut rsvd_rows = Vec::new();
    println!("{:>6} {:>14} {:>14} {:>9}", "n", "rsvd GF/s", "pooled GF/s", "speedup");
    for &n in rsvd_sizes {
        let serial = rsvd_gflops(&Pool::serial(), n, opts, &mut rng);
        let pooled = rsvd_gflops(pool::global(), n, opts, &mut rng);
        println!("{n:>6} {serial:>14.2} {pooled:>14.2} {:>8.2}x", pooled / serial);
        rsvd_rows.push(JsonValue::obj(vec![
            ("n", JsonValue::num(n as f64)),
            ("serial_gflops", JsonValue::num(serial)),
            ("pooled_gflops", JsonValue::num(pooled)),
            ("speedup", JsonValue::num(pooled / serial)),
        ]));
    }
    println!();

    // ---- time vs GaLore (measured; both via the sim path) ----
    let n = steps(120);
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, n);
    cfg.batch = 4;
    // GaLore's interval chosen as in its paper (200 ⇒ scaled to run len)
    let galore = SimTrainer::new(&cfg, Method::GaLore { interval: 40 }, 1).train(n);
    let lotus =
        SimTrainer::new(&cfg, Method::Lotus { gamma: 0.015, eta: 10, t_min: 10 }, 1).train(n);
    // compare the *update* phase (fwd/bwd is method-independent)
    let dt = 1.0 - lotus.time_update_s / galore.time_update_s;
    println!(
        "update-phase time: GaLore {:.2}s vs Lotus {:.2}s  (reduction {:.0}% — paper: ~30% end-to-end)",
        galore.time_update_s,
        lotus.time_update_s,
        dt * 100.0
    );
    let total_dt = 1.0 - lotus.total_s / galore.total_s;
    println!(
        "total time:        GaLore {:.2}s vs Lotus {:.2}s  (reduction {:.0}%)",
        galore.total_s,
        lotus.total_s,
        total_dt * 100.0
    );
    println!(
        "ppl:               GaLore {:.2} vs Lotus {:.2}  (target: Lotus <= GaLore)",
        galore.final_ppl, lotus.final_ppl
    );
    let lotus_steps_per_s = n as f64 / lotus.total_s.max(1e-9);
    let galore_steps_per_s = n as f64 / galore.total_s.max(1e-9);
    println!(
        "sim throughput:    GaLore {galore_steps_per_s:.2} steps/s vs Lotus {lotus_steps_per_s:.2} steps/s\n"
    );

    // ---- memory (analytic at paper sizes) ----
    for (label, shape, r) in
        [("60M", llama_paper_60m(), 128u64), ("1B", llama_paper_1b(), 512u64)]
    {
        let vs_full = memcount::lotus_vs_full_ratio(&shape, r, 2);
        let vs_galore = memcount::lotus_vs_galore_ratio(&shape, r, 2);
        let g = memcount::model_mem(memcount::Method::GaLore, &shape, r, 2);
        let l = memcount::model_mem(memcount::Method::Lotus, &shape, r, 2);
        println!(
            "{label}: grad+opt vs full-rank = {:.0}% saved (paper ~40%) | refresh transient: GaLore {} → Lotus {} ({:.0}% smaller) | opt+transient vs GaLore = {:.1}% saved",
            (1.0 - vs_full) * 100.0,
            lotus::util::fmt::bytes(g.transient_peak),
            lotus::util::fmt::bytes(l.transient_peak),
            (1.0 - l.transient_peak as f64 / g.transient_peak as f64) * 100.0,
            (1.0 - vs_galore) * 100.0,
        );
    }

    // ---- machine-readable record for the perf trajectory ----
    let doc = JsonValue::obj(vec![
        ("threads", JsonValue::num(threads as f64)),
        ("cores", JsonValue::num(cores as f64)),
        ("speedup_gate_applied", JsonValue::Bool(gate_applies)),
        ("matmul", JsonValue::arr(matmul_rows)),
        ("rsvd", JsonValue::arr(rsvd_rows)),
        (
            "sim",
            JsonValue::obj(vec![
                ("steps", JsonValue::num(n as f64)),
                ("galore_steps_per_s", JsonValue::num(galore_steps_per_s)),
                ("lotus_steps_per_s", JsonValue::num(lotus_steps_per_s)),
                ("galore_update_s", JsonValue::num(galore.time_update_s)),
                ("lotus_update_s", JsonValue::num(lotus.time_update_s)),
                ("update_time_reduction", JsonValue::num(dt)),
                ("galore_ppl", JsonValue::num(galore.final_ppl)),
                ("lotus_ppl", JsonValue::num(lotus.final_ppl)),
            ]),
        ),
    ]);
    let path = "BENCH_headline.json";
    std::fs::write(path, doc.to_string()).expect("writing BENCH_headline.json");
    println!("\nwrote {path}");
}
