//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this shim vendors
//! exactly the subset of anyhow's API that `lotus` uses: a string-backed
//! [`Error`], the [`Result`] alias, the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros. Context
//! is flattened into the message eagerly (`"ctx: cause"`), so `{e}` and
//! `{e:#}` both render the full chain. Swapping in the real crate is a
//! one-line `Cargo.toml` change.

use std::fmt;

/// A string-backed error value. Context added via [`Context`] is folded
/// into the message as `"context: cause"`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — a `Result` with a boxed-string error default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human-readable context to errors (and `None`s).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: ctx.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(::std::format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(::std::format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return ::std::result::Result::Err($crate::anyhow!($($t)*)) };
}

/// Early-return with an [`Error`] when the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e.into())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().contains("gone"));
    }

    #[test]
    fn context_is_prepended() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let err = r.with_context(|| "reading manifest").unwrap_err();
        assert_eq!(err.to_string(), "reading manifest: inner");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
    }

    #[test]
    fn macros_build_and_format() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("x = {x}");
        assert_eq!(b.to_string(), "x = 7");
        let c = anyhow!("y = {}", 9);
        assert_eq!(c.to_string(), "y = 9");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }
}
