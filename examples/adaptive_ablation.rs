//! Ablation playground for the adaptive switching policy: sweep γ
//! (displacement threshold) and η (verifying gap) on a real training
//! run and report how switching frequency and final perplexity respond.
//! Reproduces the paper's §3.2 guidance (γ ∈ 0.005–0.02, η ∈ 25–100).
//!
//! ```sh
//! cargo run --release --example adaptive_ablation
//! ```

use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::util::fmt::Table;

fn main() {
    let steps = 150;
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;

    println!("== Lotus AdaSS ablation: γ × η sweep ({steps} steps, tiny model) ==\n");
    let mut table = Table::new(&["gamma", "eta", "ppl", "subspaces", "freq/100"]);
    for gamma in [0.005, 0.01, 0.02, 0.05] {
        for eta in [5u64, 10, 25] {
            let method = Method::Lotus { gamma, eta, t_min: eta };
            let mut t = SimTrainer::new(&cfg, method, 11);
            let r = t.train(steps);
            table.row(&[
                format!("{gamma}"),
                eta.to_string(),
                format!("{:.2}", r.final_ppl),
                r.stats.subspace_count.to_string(),
                format!("{:.1}", r.stats.frequency_per_100()),
            ]);
        }
    }
    println!("{}", table.render());

    println!("reference points:");
    for (label, method) in [
        ("GaLore fixed-40", Method::GaLore { interval: 40 }),
        ("rSVD fixed-40 (no AdaSS)", Method::RsvdFixed { interval: 40 }),
        ("Full-rank Adam", Method::FullRank),
    ] {
        let mut t = SimTrainer::new(&cfg, method, 11);
        let r = t.train(steps);
        println!(
            "  {label:<26} ppl {:.2}  subspaces {}",
            r.final_ppl, r.stats.subspace_count
        );
    }
    println!("\nexpected shape: higher γ / smaller η → more switches; extreme values hurt ppl.");
}
