//! Quickstart: train a tiny LLaMA with Lotus in ~a minute on CPU, using
//! the Rust-native simulator (no artifacts needed), and print what the
//! adaptive subspace switching did.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use lotus::models::presets::llama_tiny_cfg;
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::util::fmt;

fn main() {
    let steps = 150;
    let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, steps);
    cfg.batch = 4;

    println!("== Lotus quickstart ==");
    println!(
        "model: d={} L={} vocab={} (~{} params), rank={}",
        cfg.model.d_model,
        cfg.model.n_layers,
        cfg.model.vocab,
        fmt::params(cfg.model.param_count()),
        cfg.rank
    );

    // Lotus: rSVD projector + adaptive displacement switching (Alg. 1)
    let method = Method::Lotus { gamma: 0.015, eta: 10, t_min: 10 };
    let mut trainer = SimTrainer::new(&cfg, method, 42);
    let ppl0 = trainer.eval_ppl(4);
    println!("initial ppl: {ppl0:.1}");

    let report = trainer.train(steps);
    println!("\nloss curve (every 10 steps):");
    for (step, loss) in report.loss_curve.iter().take(16) {
        let bar = "#".repeat((loss * 8.0) as usize);
        println!("  step {step:>4}  loss {loss:.3}  {bar}");
    }
    println!("\nfinal eval ppl: {:.1} (from {ppl0:.1})", report.final_ppl);
    println!(
        "subspaces instantiated: {} across {} layer-steps ({:.1} switches/100)",
        report.stats.subspace_count,
        report.stats.observations,
        report.stats.frequency_per_100()
    );
    println!(
        "optimizer state held: {} (full-rank Adam would hold {})",
        fmt::bytes(report.state_bytes),
        fmt::bytes(3 * 4 * cfg.model.param_count()) // grads+2 moments, f32
    );
    println!(
        "time: grad {:.1}s / update {:.1}s",
        report.time_grad_s, report.time_update_s
    );
    println!("\nnext: examples/pretrain_c4.rs (PJRT path), benches/table1.rs (paper table)");
}
