//! Fine-tune the encoder on the 8 GLUE-sim tasks with Lotus and print a
//! Table-2-style report (per-task metric, average, memory, switching).
//!
//! ```sh
//! cargo run --release --example finetune_glue -- [method] [rank]
//!   method  lotus | galore | lora | apollo | full   (default lotus)
//!   rank    default 8
//! ```

use lotus::data::glue::generate_suite;
use lotus::models::presets::encoder_small_cfg;
use lotus::optim::Hyper;
use lotus::sim::finetune_task;
use lotus::sim::trainer::Method;
use lotus::util::fmt::{self, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let method_name = args.first().map(|s| s.as_str()).unwrap_or("lotus");
    let rank: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let method = match method_name {
        "lotus" => Method::Lotus { gamma: 0.01, eta: 10, t_min: 10 },
        "galore" => Method::GaLore { interval: 100 },
        "lora" => Method::LoRA,
        "apollo" => Method::Apollo { refresh_every: 100 },
        "full" => Method::FullRank,
        other => {
            eprintln!("unknown method '{other}'");
            std::process::exit(2);
        }
    };

    let enc = encoder_small_cfg();
    let suite = generate_suite(enc.vocab, enc.seq_len, 2026);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };

    println!("== GLUE-sim fine-tuning: {} (rank {rank}) ==", method.name());
    println!(
        "encoder: d={} L={} (~{} params), 8 tasks, 2 epochs\n",
        enc.d_model,
        enc.n_layers,
        fmt::params(enc.param_count())
    );

    let mut table = Table::new(&["Task", "Metric", "Kind", "Subspaces", "Time"]);
    let mut total = 0.0;
    for task in &suite {
        let r = finetune_task(&enc, task, method, rank, 2, 8, &hyper, 1);
        total += r.metric;
        table.row(&[
            task.name.to_string(),
            format!("{:.2}", r.metric),
            format!("{:?}", task.kind),
            r.stats.subspace_count.to_string(),
            fmt::duration_s(r.wall_s),
        ]);
    }
    table.row(&[
        "Avg".into(),
        format!("{:.2}", total / suite.len() as f64),
        "".into(),
        "".into(),
        "".into(),
    ]);
    println!("{}", table.render());
    println!("(paper Table 2 avg @ rank 8: GaLore 85.94, Lotus 86.99 — ordering is the target)");
}
