//! **E2E validation driver** (DESIGN.md §6): pre-train a LLaMA-family
//! model through the full three-layer stack — Rust coordinator → PJRT
//! artifacts (JAX-lowered fwd/bwd + Pallas-kernel optimizer steps) — on
//! the synthetic C4-like corpus, logging the loss curve and subspace
//! switches to runs/.
//!
//! ```sh
//! make artifacts                      # once (tiny + 20m configs)
//! cargo run --release --example pretrain_c4 -- [steps] [config]
//!   steps   default 300
//!   config  tiny | 20m   (default 20m; 20m ≈ 22M params)
//! ```
//!
//! The recorded run for EXPERIMENTS.md uses the defaults.

use lotus::config::RunConfig;
use lotus::models::presets::{llama_20m_cfg, llama_tiny_cfg};
use lotus::sim::trainer::Method;
use lotus::train::{HostParams, PjrtTrainer};
use lotus::util::fmt;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let which = args.get(1).map(|s| s.as_str()).unwrap_or("20m");

    let mut cfg = RunConfig::default();
    (cfg.model, cfg.batch, cfg.method.rank) = match which {
        "tiny" => (llama_tiny_cfg(), 4, 16),
        "20m" => (llama_20m_cfg(), 8, 64),
        other => anyhow::bail!("unknown config '{other}' (tiny|20m)"),
    };
    cfg.steps = steps;
    cfg.name = format!("pretrain-c4sim-{which}");
    cfg.hyper.lr = 3e-3;
    cfg.hyper.galore_scale = 1.0;
    cfg.ckpt_every = if steps >= 100 { 100 } else { 0 };

    let n_params = HostParams::init(cfg.model, cfg.seed).param_count();
    println!("== Lotus E2E pre-training (PJRT path) ==");
    println!(
        "model {which}: {} params | batch {} seq {} | {} steps | rank {}",
        fmt::params(n_params),
        cfg.batch,
        cfg.model.seq_len,
        steps,
        cfg.method.rank
    );
    println!("method: Lotus (γ=0.01, η=50, T_min=50) — Algorithm 1 on the coordinator\n");

    let method = Method::Lotus { gamma: 0.01, eta: 50, t_min: 50 };
    let t0 = std::time::Instant::now();
    let mut trainer = PjrtTrainer::new(cfg.clone(), method)?;
    println!("(artifact compile + warmup: {:.1}s)\n", t0.elapsed().as_secs_f64());

    let report = trainer.train(steps)?;

    println!("\nloss curve:");
    let show = report.loss_curve.len().min(30);
    let stride = (report.loss_curve.len() / show).max(1);
    for (step, loss) in report.loss_curve.iter().step_by(stride) {
        let bar = "#".repeat((loss * 6.0) as usize);
        println!("  step {step:>5}  loss {loss:.3}  {bar}");
    }
    println!(
        "\nfinal: loss {:.4} (ppl {:.1}) after {} steps ({} tokens)",
        report.final_loss,
        report.final_ppl,
        steps,
        fmt::params(steps * (cfg.batch * cfg.model.seq_len) as u64),
    );
    println!(
        "subspace switches: {} (init {} / adaptive {})",
        report.stats.subspace_count,
        report.stats.by_reason[3],
        report.stats.by_reason[1]
    );
    println!(
        "time: fwdbwd {} | update {} | refresh {} | compile {} | total {}",
        fmt::duration_s(report.time_fwdbwd_s),
        fmt::duration_s(report.time_update_s),
        fmt::duration_s(report.time_refresh_s),
        fmt::duration_s(report.compile_s),
        fmt::duration_s(report.total_s),
    );
    println!("metrics: {}/{}.jsonl", cfg.out_dir, cfg.name);
    Ok(())
}
